//! Typed wire messages of every protocol the coordinator speaks, with
//! their [`Wire`] codecs.
//!
//! The entire point of the paper's communication design is visible in the
//! types: an SFW-asyn worker sends `{u, v, t_w}` — O(D1 + D2) floats —
//! and the master replies with the update-log slice `{(u_k, v_k)}
//! k = t_w+1..t_m` — again O(D1 + D2) per entry — instead of
//! gradient/parameter matrices of size O(D1 * D2).  The synchronous
//! SFW-dist baseline ships exactly those dense matrices ([`DistUp`] /
//! [`DistDown`]), which is what makes the contrast measurable on the same
//! wire.  `wire_bytes()` on each message is derived from the actual
//! encoding (see [`Wire`]), and is what the comm-cost bench measures.
//!
//! [`Wire`]: crate::comms::Wire

use std::sync::Arc;

use crate::comms::{Dec, Enc, Wire, WireError};
use crate::linalg::Mat;

// ------------------------------------------------- SFW-asyn / SVRF-asyn

/// Frame tags of the asynchronous rank-one protocol (Algorithms 3/5).
pub const TAG_UPDATE: u8 = 1;
pub const TAG_UPDATES: u8 = 2;
pub const TAG_STOP: u8 = 3;
pub const TAG_UPDATE_W: u8 = 4;

/// Rank-one LMO result sent worker -> master: `{u_w, v_w, t_w}` plus the
/// minibatch loss ride-along (f32 telemetry, negligible on the wire).
#[derive(Clone, Debug)]
pub struct UpdateMsg {
    pub worker_id: u32,
    /// Iteration of the model copy the update was computed against.
    pub t_w: u64,
    pub u: Vec<f32>,
    pub v: Vec<f32>,
    pub sigma: f32,
    pub loss_sum: f64,
    /// True minibatch size used.
    pub m: u32,
}

impl UpdateMsg {
    /// Leading payload bytes the chaos layer must not bit-flip: the
    /// `worker_id` routing field.  A flipped rank would misroute the
    /// master's reply (Byzantine misrouting — out of scope for the
    /// rank-addressed reply protocol); everything after it — `t_w`,
    /// telemetry, the update vectors — is fair corruption game, handled
    /// by the master's semantic gates.
    pub const CORRUPT_GUARD: usize = 4;
}

impl Wire for UpdateMsg {
    fn tag(&self) -> u8 {
        TAG_UPDATE
    }

    /// O(1) closed form of the encoded frame size; pinned equal to the
    /// real encoding by `tests/properties.rs::wire_bytes_exact`.
    fn wire_bytes(&self) -> u64 {
        crate::comms::FRAME_HEADER as u64
            + (4 + 8 + 4 + 8 + 4 + 4 + 4) as u64
            + 4 * (self.u.len() + self.v.len()) as u64
    }

    fn encode(&self, buf: &mut Vec<u8>) {
        let mut e = Enc(buf);
        e.u32(self.worker_id);
        e.u64(self.t_w);
        e.f32(self.sigma);
        e.f64(self.loss_sum);
        e.u32(self.m);
        e.f32s(&self.u);
        e.f32s(&self.v);
    }

    fn decode(tag: u8, payload: &[u8]) -> Result<Self, WireError> {
        if tag != TAG_UPDATE {
            return Err(WireError::BadTag(tag));
        }
        let mut d = Dec::new(payload);
        let msg = UpdateMsg {
            worker_id: d.u32()?,
            t_w: d.u64()?,
            sigma: d.f32()?,
            loss_sum: d.f64()?,
            m: d.u32()?,
            u: d.f32s()?,
            v: d.f32s()?,
        };
        d.finish()?;
        Ok(msg)
    }
}

/// One entry of the master's update log: iterate recursion Eqn (6)
/// `X_k = (1 - eta_k) X_{k-1} + eta_k * scale * u_k v_k^T`
/// (`scale = -theta` for the nuclear-ball LMO direction).  `Arc`ed so the
/// master can hand log slices to workers without copying the vectors.
#[derive(Clone, Debug)]
pub struct LogEntry {
    /// Master iteration k this entry produced (1-based).
    pub k: u64,
    pub eta: f32,
    pub scale: f32,
    pub u: Arc<Vec<f32>>,
    pub v: Arc<Vec<f32>>,
}

impl LogEntry {
    /// Payload bytes this entry contributes to a framed [`MasterMsg`]
    /// (pinned to the codec by the wire-bytes property tests).  Used by
    /// the queuing simulator, which accounts per-entry catch-up traffic
    /// without constructing messages.
    pub fn wire_bytes(&self) -> u64 {
        (8 + 4 + 4 + 4 + 4) as u64 + 4 * (self.u.len() + self.v.len()) as u64
    }
}

/// Master -> worker reply.
#[derive(Clone, Debug)]
pub enum MasterMsg {
    /// Catch-up slice: everything the worker missed, `t_w+1 ..= t_m`.
    Updates { t_m: u64, entries: Vec<LogEntry> },
    /// SVRF epoch boundary (Algorithm 5's update-W signal): replay to
    /// `t_m`, snapshot W, recompute the full gradient at W.
    UpdateW { t_m: u64, entries: Vec<LogEntry> },
    Stop,
}

fn encode_entries(buf: &mut Vec<u8>, t_m: u64, entries: &[LogEntry]) {
    let mut e = Enc(buf);
    e.u64(t_m);
    e.u32(entries.len() as u32);
    for le in entries {
        e.u64(le.k);
        e.f32(le.eta);
        e.f32(le.scale);
        e.f32s(&le.u);
        e.f32s(&le.v);
    }
}

fn decode_entries(payload: &[u8]) -> Result<(u64, Vec<LogEntry>), WireError> {
    let mut d = Dec::new(payload);
    let t_m = d.u64()?;
    let n = d.u32()? as usize;
    let mut entries = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        entries.push(LogEntry {
            k: d.u64()?,
            eta: d.f32()?,
            scale: d.f32()?,
            u: Arc::new(d.f32s()?),
            v: Arc::new(d.f32s()?),
        });
    }
    d.finish()?;
    Ok((t_m, entries))
}

impl Wire for MasterMsg {
    fn tag(&self) -> u8 {
        match self {
            MasterMsg::Updates { .. } => TAG_UPDATES,
            MasterMsg::UpdateW { .. } => TAG_UPDATE_W,
            MasterMsg::Stop => TAG_STOP,
        }
    }

    /// O(1)-per-entry closed form, pinned to the codec by property test.
    fn wire_bytes(&self) -> u64 {
        let header = crate::comms::FRAME_HEADER as u64;
        match self {
            MasterMsg::Stop => header,
            MasterMsg::Updates { entries, .. } | MasterMsg::UpdateW { entries, .. } => {
                header + (8 + 4) as u64 + entries.iter().map(|e| e.wire_bytes()).sum::<u64>()
            }
        }
    }

    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            MasterMsg::Stop => {}
            MasterMsg::Updates { t_m, entries } | MasterMsg::UpdateW { t_m, entries } => {
                encode_entries(buf, *t_m, entries);
            }
        }
    }

    fn decode(tag: u8, payload: &[u8]) -> Result<Self, WireError> {
        match tag {
            TAG_STOP => {
                // strict like every other variant: Stop carries no payload
                if !payload.is_empty() {
                    return Err(WireError::Trailing(payload.len()));
                }
                Ok(MasterMsg::Stop)
            }
            TAG_UPDATES => {
                let (t_m, entries) = decode_entries(payload)?;
                Ok(MasterMsg::Updates { t_m, entries })
            }
            TAG_UPDATE_W => {
                let (t_m, entries) = decode_entries(payload)?;
                Ok(MasterMsg::UpdateW { t_m, entries })
            }
            t => Err(WireError::BadTag(t)),
        }
    }
}

// --------------------------------------------------------- SFW-dist

/// Frame tags of the synchronous SFW-dist protocol (Algorithm 1).
pub const TAG_DIST_GRAD: u8 = 1;
pub const TAG_DIST_COMPUTE: u8 = 1;
pub const TAG_DIST_STOP: u8 = 2;
pub const TAG_DIST_COMPUTE_FACTORED: u8 = 3;

/// Worker -> master round reply: the dense partial gradient —
/// O(D1 * D2) on the wire, the cost the paper's protocol eliminates.
/// Carries the round index `k` it answers, so the barrier can discard
/// duplicated or straggling frames from earlier rounds instead of
/// folding a stale gradient into the wrong reduction.
#[derive(Clone, Debug)]
pub struct DistUp {
    pub worker_id: u32,
    /// Round (master iteration) this reply answers — echoed from
    /// [`DistDown::Compute`].
    pub k: u64,
    /// Minibatch loss telemetry (kept on the wire for parity with Alg 3;
    /// the master reports full-objective loss via the evaluator).
    pub loss_sum: f64,
    pub grad: Mat,
}

impl DistUp {
    /// Leading payload bytes the chaos layer must not bit-flip:
    /// `worker_id` (reply routing) and `k` (barrier identity).  A
    /// flipped round index would make the barrier wait forever for a
    /// reply that already arrived under the wrong round — the
    /// synchronous protocol has no retransmission to recover with.
    pub const CORRUPT_GUARD: usize = 4 + 8;
}

impl Wire for DistUp {
    fn tag(&self) -> u8 {
        TAG_DIST_GRAD
    }

    /// O(1) closed form, pinned to the codec by property test.
    fn wire_bytes(&self) -> u64 {
        crate::comms::FRAME_HEADER as u64
            + (4 + 8 + 8 + 4 + 4) as u64
            + 4 * self.grad.data.len() as u64
    }

    fn encode(&self, buf: &mut Vec<u8>) {
        let mut e = Enc(buf);
        e.u32(self.worker_id);
        e.u64(self.k);
        e.f64(self.loss_sum);
        e.mat(&self.grad);
    }

    fn decode(tag: u8, payload: &[u8]) -> Result<Self, WireError> {
        if tag != TAG_DIST_GRAD {
            return Err(WireError::BadTag(tag));
        }
        let mut d = Dec::new(payload);
        let msg = DistUp { worker_id: d.u32()?, k: d.u64()?, loss_sum: d.f64()?, grad: d.mat()? };
        d.finish()?;
        Ok(msg)
    }
}

/// Master -> worker round broadcast.  The dense variant ships the full
/// iterate plus each worker's minibatch share — O(D1 * D2) per worker
/// per round, `Arc`ed so the local transport's per-worker broadcast is a
/// refcount bump, not W deep copies.  The factored variant ships only
/// the rank-one atoms appended since the previous round — the same
/// [`LogEntry`]s the asynchronous protocol replays — cutting the
/// downlink to O((D1 + D2) * new-atoms) per worker per round (workers
/// reconstruct X locally from the shared-seed X_0; see
/// `coordinator::sync`).
#[derive(Clone, Debug)]
pub enum DistDown {
    Compute { k: u64, m_share: u32, x: Arc<Mat> },
    /// Factored-downlink round: atoms since the last broadcast (0 or 1
    /// in the lockstep barrier protocol; a slice after skipped rounds).
    ComputeFactored { k: u64, m_share: u32, entries: Vec<LogEntry> },
    Stop,
}

impl Wire for DistDown {
    fn tag(&self) -> u8 {
        match self {
            DistDown::Compute { .. } => TAG_DIST_COMPUTE,
            DistDown::ComputeFactored { .. } => TAG_DIST_COMPUTE_FACTORED,
            DistDown::Stop => TAG_DIST_STOP,
        }
    }

    /// O(1)-per-entry closed form, pinned to the codec by property test.
    fn wire_bytes(&self) -> u64 {
        let header = crate::comms::FRAME_HEADER as u64;
        match self {
            DistDown::Stop => header,
            DistDown::Compute { x, .. } => {
                header + (8 + 4 + 4 + 4) as u64 + 4 * x.data.len() as u64
            }
            DistDown::ComputeFactored { entries, .. } => {
                header
                    + (8 + 4 + 4) as u64
                    + entries.iter().map(|e| e.wire_bytes()).sum::<u64>()
            }
        }
    }

    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            DistDown::Stop => {}
            DistDown::Compute { k, m_share, x } => {
                let mut e = Enc(buf);
                e.u64(*k);
                e.u32(*m_share);
                e.mat(x);
            }
            DistDown::ComputeFactored { k, m_share, entries } => {
                let mut e = Enc(buf);
                e.u64(*k);
                e.u32(*m_share);
                e.u32(entries.len() as u32);
                for le in entries {
                    e.u64(le.k);
                    e.f32(le.eta);
                    e.f32(le.scale);
                    e.f32s(&le.u);
                    e.f32s(&le.v);
                }
            }
        }
    }

    fn decode(tag: u8, payload: &[u8]) -> Result<Self, WireError> {
        match tag {
            TAG_DIST_STOP => {
                if !payload.is_empty() {
                    return Err(WireError::Trailing(payload.len()));
                }
                Ok(DistDown::Stop)
            }
            TAG_DIST_COMPUTE => {
                let mut d = Dec::new(payload);
                let msg = DistDown::Compute {
                    k: d.u64()?,
                    m_share: d.u32()?,
                    x: Arc::new(d.mat()?),
                };
                d.finish()?;
                Ok(msg)
            }
            TAG_DIST_COMPUTE_FACTORED => {
                let mut d = Dec::new(payload);
                let k = d.u64()?;
                let m_share = d.u32()?;
                let n = d.u32()? as usize;
                let mut entries = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    entries.push(LogEntry {
                        k: d.u64()?,
                        eta: d.f32()?,
                        scale: d.f32()?,
                        u: Arc::new(d.f32s()?),
                        v: Arc::new(d.f32s()?),
                    });
                }
                d.finish()?;
                Ok(DistDown::ComputeFactored { k, m_share, entries })
            }
            t => Err(WireError::BadTag(t)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::FRAME_HEADER;

    fn entry(k: u64, d1: usize, d2: usize) -> LogEntry {
        LogEntry {
            k,
            eta: 0.5,
            scale: -1.0,
            u: Arc::new(vec![0.0; d1]),
            v: Arc::new(vec![0.0; d2]),
        }
    }

    #[test]
    fn update_msg_is_linear_in_d1_plus_d2() {
        let m = UpdateMsg {
            worker_id: 0,
            t_w: 10,
            u: vec![0.0; 30],
            v: vec![0.0; 40],
            sigma: 1.0,
            loss_sum: 0.0,
            m: 64,
        };
        // 5-byte frame header + 36-byte payload header + 4*(30+40)
        assert_eq!(m.wire_bytes(), (FRAME_HEADER + 36) as u64 + 280);
        // crucially NOT 4 * 30 * 40 (the dense-gradient cost)
        assert!(m.wire_bytes() < 4 * 30 * 40);
    }

    #[test]
    fn master_msg_bytes_scale_with_entry_count() {
        let one = MasterMsg::Updates { t_m: 1, entries: vec![entry(1, 30, 40)] };
        let three = MasterMsg::Updates {
            t_m: 3,
            entries: vec![entry(1, 30, 40), entry(2, 30, 40), entry(3, 30, 40)],
        };
        // the per-entry cost the simulator uses matches the real codec
        let per_entry = entry(0, 30, 40).wire_bytes();
        assert_eq!(three.wire_bytes() - one.wire_bytes(), 2 * per_entry);
        // Stop is a bare frame header
        assert_eq!(MasterMsg::Stop.wire_bytes(), FRAME_HEADER as u64);
    }

    #[test]
    fn asyn_codec_round_trips() {
        let m = UpdateMsg {
            worker_id: 3,
            t_w: 17,
            u: vec![1.0, -2.5, 3.25],
            v: vec![0.5, 4.0],
            sigma: 6.5,
            loss_sum: 2.25,
            m: 99,
        };
        let mut buf = Vec::new();
        m.encode(&mut buf);
        let d = UpdateMsg::decode(m.tag(), &buf).unwrap();
        assert_eq!((d.worker_id, d.t_w, d.m), (3, 17, 99));
        assert_eq!(d.u, m.u);
        assert_eq!(d.v, m.v);

        let msg = MasterMsg::Updates { t_m: 5, entries: vec![entry(5, 2, 1)] };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        match MasterMsg::decode(msg.tag(), &buf).unwrap() {
            MasterMsg::Updates { t_m, entries } => {
                assert_eq!(t_m, 5);
                assert_eq!(entries.len(), 1);
                assert_eq!(entries[0].k, 5);
            }
            _ => panic!("wrong variant"),
        }
        assert!(matches!(MasterMsg::decode(TAG_STOP, &[]).unwrap(), MasterMsg::Stop));
        assert!(MasterMsg::decode(77, &[]).is_err());
        // a garbage payload under a Stop tag is corruption, not a Stop
        assert!(MasterMsg::decode(TAG_STOP, &[1]).is_err());
        assert!(DistDown::decode(TAG_DIST_STOP, &[1]).is_err());
    }

    #[test]
    fn dist_messages_cost_d1_times_d2() {
        let x = Mat::zeros(30, 40);
        let down = DistDown::Compute { k: 1, m_share: 16, x: Arc::new(x.clone()) };
        let up = DistUp { worker_id: 0, k: 1, loss_sum: 0.0, grad: x };
        // both directions carry the dense matrix: >= 4 * D1 * D2 bytes
        assert!(down.wire_bytes() >= 4 * 30 * 40);
        assert!(up.wire_bytes() >= 4 * 30 * 40);
        assert_eq!(DistDown::Stop.wire_bytes(), FRAME_HEADER as u64);
    }

    #[test]
    fn factored_dist_downlink_costs_d1_plus_d2() {
        // One new atom per round: the factored broadcast is linear in
        // D1 + D2 where the dense broadcast is D1 * D2 — the tentpole's
        // whole point, on the wire.
        let factored =
            DistDown::ComputeFactored { k: 5, m_share: 16, entries: vec![entry(5, 30, 40)] };
        let dense =
            DistDown::Compute { k: 5, m_share: 16, x: Arc::new(Mat::zeros(30, 40)) };
        assert!(factored.wire_bytes() < 8 * 4 * (30 + 40));
        assert!(dense.wire_bytes() >= 4 * 30 * 40);
        assert!(factored.wire_bytes() * 4 < dense.wire_bytes());
        // an empty round ships a near-bare frame
        let empty = DistDown::ComputeFactored { k: 6, m_share: 16, entries: Vec::new() };
        assert_eq!(empty.wire_bytes(), (FRAME_HEADER + 8 + 4 + 4) as u64);
    }

    #[test]
    fn factored_dist_downlink_round_trips() {
        let msg = DistDown::ComputeFactored {
            k: 9,
            m_share: 8,
            entries: vec![entry(9, 3, 2), entry(10, 3, 2)],
        };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        match DistDown::decode(msg.tag(), &buf).unwrap() {
            DistDown::ComputeFactored { k, m_share, entries } => {
                assert_eq!((k, m_share), (9, 8));
                assert_eq!(entries.len(), 2);
                assert_eq!(entries[1].k, 10);
                assert_eq!(entries[0].u.len(), 3);
                assert_eq!(entries[0].v.len(), 2);
            }
            other => panic!("wrong variant {other:?}"),
        }
        // truncation errors, never panics
        assert!(DistDown::decode(TAG_DIST_COMPUTE_FACTORED, &buf[..buf.len() - 2]).is_err());
    }

    #[test]
    fn truncated_frames_error_not_panic() {
        let m = UpdateMsg {
            worker_id: 1,
            t_w: 2,
            u: vec![1.0; 4],
            v: vec![1.0; 4],
            sigma: 0.0,
            loss_sum: 0.0,
            m: 1,
        };
        let mut buf = Vec::new();
        m.encode(&mut buf);
        assert!(UpdateMsg::decode(m.tag(), &buf[..buf.len() - 3]).is_err());
        let mut extended = buf.clone();
        extended.push(0);
        assert!(UpdateMsg::decode(m.tag(), &extended).is_err());
    }
}
