//! Typed wire messages of every protocol the coordinator speaks, with
//! their [`Wire`] codecs.
//!
//! The entire point of the paper's communication design is visible in the
//! types: an SFW-asyn worker sends `{u, v, t_w}` — O(D1 + D2) floats —
//! and the master replies with the update-log slice `{(u_k, v_k)}
//! k = t_w+1..t_m` — again O(D1 + D2) per entry — instead of
//! gradient/parameter matrices of size O(D1 * D2).  The synchronous
//! SFW-dist baseline ships exactly those dense matrices ([`DistUp`] /
//! [`DistDown`]), which is what makes the contrast measurable on the same
//! wire.  `wire_bytes()` on each message is derived from the actual
//! encoding (see [`Wire`]), and is what the comm-cost bench measures.
//!
//! [`Wire`]: crate::comms::Wire

use std::sync::Arc;

use crate::comms::grad_codec::{
    bf16_bits, bf16_from_bits, bf16_truncate, int8_dequant, int8_quant, int8_scale,
};
use crate::comms::{Dec, Enc, GradCodec, Wire, WireError};
use crate::linalg::Mat;

// ------------------------------------------------- SFW-asyn / SVRF-asyn

/// Frame tags of the asynchronous rank-one protocol (Algorithms 3/5).
/// The `_BF16`/`_INT8` tags are the compressed-uplink spellings of
/// `TAG_UPDATE` (`--uplink`; see [`GradCodec`] and the `sfw::comms`
/// module docs for the codec contract).
pub const TAG_UPDATE: u8 = 1;
pub const TAG_UPDATES: u8 = 2;
pub const TAG_STOP: u8 = 3;
pub const TAG_UPDATE_W: u8 = 4;
pub const TAG_UPDATE_BF16: u8 = 5;
pub const TAG_UPDATE_INT8: u8 = 6;

/// Decode a length-prefixed bf16 vector back to f32.
fn decode_bf16s(d: &mut Dec) -> Result<Vec<f32>, WireError> {
    let n = d.u32()? as usize;
    let nb = n.checked_mul(2).ok_or(WireError::Malformed("vector length overflow"))?;
    let bytes = d.raw(nb)?;
    Ok(bytes
        .chunks_exact(2)
        .map(|c| bf16_from_bits(u16::from_le_bytes([c[0], c[1]])))
        .collect())
}

/// Decode a length-prefixed int8 vector against its scale.
fn decode_i8s(d: &mut Dec, s: f32) -> Result<Vec<f32>, WireError> {
    let n = d.u32()? as usize;
    let bytes = d.raw(n)?;
    Ok(bytes.iter().map(|&b| int8_dequant(b as i8, s)).collect())
}

/// Rank-one LMO result sent worker -> master: `{u_w, v_w, t_w}` plus the
/// minibatch loss ride-along (f32 telemetry, negligible on the wire).
///
/// Under a lossy uplink codec the vectors are quantized **once, at
/// construction** ([`UpdateMsg::quantized`]): the struct stores the
/// dequantized values plus the int8 scales, so encode -> decode is the
/// identity and every transport delivers bit-identical atoms.
#[derive(Clone, Debug, PartialEq)]
pub struct UpdateMsg {
    pub worker_id: u32,
    /// Iteration of the model copy the update was computed against.
    pub t_w: u64,
    pub u: Vec<f32>,
    pub v: Vec<f32>,
    pub sigma: f32,
    pub loss_sum: f64,
    /// True minibatch size used.
    pub m: u32,
    /// Minibatch FW dual-gap estimate at the worker's model copy —
    /// the master's stopping quantity (--tol); only the worker holds the
    /// gradient needed to compute it, so it rides the uplink as telemetry.
    pub gap: f64,
    /// Uplink codec this message is framed with (picks the frame tag).
    pub codec: GradCodec,
    /// Per-vector int8 scales (0.0 unless `codec == Int8`).
    pub u_scale: f32,
    pub v_scale: f32,
}

impl UpdateMsg {
    /// Leading payload bytes the chaos layer must not bit-flip: the
    /// `worker_id` routing field.  A flipped rank would misroute the
    /// master's reply (Byzantine misrouting — out of scope for the
    /// rank-addressed reply protocol); everything after it — `t_w`,
    /// telemetry, the update vectors — is fair corruption game, handled
    /// by the master's semantic gates.  Every codec variant shares this
    /// prefix, so one guard covers all three tags.
    pub const CORRUPT_GUARD: usize = 4;

    /// Uncompressed (f32) update — the default protocol message, with
    /// the legacy wire layout.
    #[allow(clippy::too_many_arguments)]
    pub fn dense(
        worker_id: u32,
        t_w: u64,
        u: Vec<f32>,
        v: Vec<f32>,
        sigma: f32,
        loss_sum: f64,
        m: u32,
        gap: f64,
    ) -> Self {
        Self::quantized(GradCodec::F32, worker_id, t_w, u, v, sigma, loss_sum, m, gap)
    }

    /// Quantize `{u, v}` through `codec` (identity for `F32`).  Plain
    /// quantization, no error feedback: the atoms are unit-normalized
    /// directions gated by the master's `sane_rank_one` check, and the
    /// per-entry error (<= 1/254 of the max entry for int8) stays far
    /// inside that gate's norm window.
    #[allow(clippy::too_many_arguments)]
    pub fn quantized(
        codec: GradCodec,
        worker_id: u32,
        t_w: u64,
        mut u: Vec<f32>,
        mut v: Vec<f32>,
        sigma: f32,
        loss_sum: f64,
        m: u32,
        gap: f64,
    ) -> Self {
        let (mut u_scale, mut v_scale) = (0.0f32, 0.0f32);
        match codec {
            GradCodec::F32 => {}
            GradCodec::Bf16 => {
                for x in u.iter_mut().chain(v.iter_mut()) {
                    *x = bf16_truncate(*x);
                }
            }
            GradCodec::Int8 => {
                u_scale = int8_scale(&u);
                v_scale = int8_scale(&v);
                for x in u.iter_mut() {
                    *x = int8_dequant(int8_quant(*x, u_scale), u_scale);
                }
                for x in v.iter_mut() {
                    *x = int8_dequant(int8_quant(*x, v_scale), v_scale);
                }
            }
        }
        UpdateMsg { worker_id, t_w, u, v, sigma, loss_sum, m, gap, codec, u_scale, v_scale }
    }
}

impl Wire for UpdateMsg {
    fn tag(&self) -> u8 {
        match self.codec {
            GradCodec::F32 => TAG_UPDATE,
            GradCodec::Bf16 => TAG_UPDATE_BF16,
            GradCodec::Int8 => TAG_UPDATE_INT8,
        }
    }

    /// O(1) closed form of the encoded frame size per codec; pinned
    /// equal to the real encoding by `tests/properties.rs::wire_bytes_exact`.
    fn wire_bytes(&self) -> u64 {
        let header =
            crate::comms::FRAME_HEADER as u64 + (4 + 8 + 4 + 8 + 4 + 8 + 4 + 4) as u64;
        let n = (self.u.len() + self.v.len()) as u64;
        match self.codec {
            GradCodec::F32 => header + 4 * n,
            GradCodec::Bf16 => header + 2 * n,
            // two per-vector f32 scales + 1 byte/entry
            GradCodec::Int8 => header + 8 + n,
        }
    }

    fn encode(&self, buf: &mut Vec<u8>) {
        let mut e = Enc(buf);
        e.u32(self.worker_id);
        e.u64(self.t_w);
        e.f32(self.sigma);
        e.f64(self.loss_sum);
        e.u32(self.m);
        e.f64(self.gap);
        match self.codec {
            GradCodec::F32 => {
                e.f32s(&self.u);
                e.f32s(&self.v);
            }
            GradCodec::Bf16 => {
                for vec in [&self.u, &self.v] {
                    e.u32(vec.len() as u32);
                    for &x in vec.iter() {
                        e.u16(bf16_bits(x));
                    }
                }
            }
            GradCodec::Int8 => {
                for (vec, s) in [(&self.u, self.u_scale), (&self.v, self.v_scale)] {
                    e.f32(s);
                    e.u32(vec.len() as u32);
                    for &x in vec.iter() {
                        e.0.push(int8_quant(x, s) as u8);
                    }
                }
            }
        }
    }

    fn decode(tag: u8, payload: &[u8]) -> Result<Self, WireError> {
        let codec = match tag {
            TAG_UPDATE => GradCodec::F32,
            TAG_UPDATE_BF16 => GradCodec::Bf16,
            TAG_UPDATE_INT8 => GradCodec::Int8,
            t => return Err(WireError::BadTag(t)),
        };
        let mut d = Dec::new(payload);
        let worker_id = d.u32()?;
        let t_w = d.u64()?;
        let sigma = d.f32()?;
        let loss_sum = d.f64()?;
        let m = d.u32()?;
        let gap = d.f64()?;
        let (mut u_scale, mut v_scale) = (0.0f32, 0.0f32);
        let (u, v) = match codec {
            GradCodec::F32 => (d.f32s()?, d.f32s()?),
            GradCodec::Bf16 => (decode_bf16s(&mut d)?, decode_bf16s(&mut d)?),
            GradCodec::Int8 => {
                u_scale = d.f32()?;
                let u = decode_i8s(&mut d, u_scale)?;
                v_scale = d.f32()?;
                let v = decode_i8s(&mut d, v_scale)?;
                (u, v)
            }
        };
        d.finish()?;
        Ok(UpdateMsg { worker_id, t_w, u, v, sigma, loss_sum, m, gap, codec, u_scale, v_scale })
    }
}

/// One entry of the master's update log: iterate recursion Eqn (6)
/// `X_k = (1 - eta_k) X_{k-1} + eta_k * scale * u_k v_k^T`
/// (`scale = -theta` for the nuclear-ball LMO direction).  `Arc`ed so the
/// master can hand log slices to workers without copying the vectors.
#[derive(Clone, Debug)]
pub struct LogEntry {
    /// Master iteration k this entry produced (1-based).
    pub k: u64,
    pub eta: f32,
    pub scale: f32,
    pub u: Arc<Vec<f32>>,
    pub v: Arc<Vec<f32>>,
}

impl LogEntry {
    /// Payload bytes this entry contributes to a framed [`MasterMsg`]
    /// (pinned to the codec by the wire-bytes property tests).  Used by
    /// the queuing simulator, which accounts per-entry catch-up traffic
    /// without constructing messages.
    pub fn wire_bytes(&self) -> u64 {
        (8 + 4 + 4 + 4 + 4) as u64 + 4 * (self.u.len() + self.v.len()) as u64
    }
}

/// Master -> worker reply.
#[derive(Clone, Debug)]
pub enum MasterMsg {
    /// Catch-up slice: everything the worker missed, `t_w+1 ..= t_m`.
    Updates { t_m: u64, entries: Vec<LogEntry> },
    /// SVRF epoch boundary (Algorithm 5's update-W signal): replay to
    /// `t_m`, snapshot W, recompute the full gradient at W.
    UpdateW { t_m: u64, entries: Vec<LogEntry> },
    Stop,
}

fn encode_entries(buf: &mut Vec<u8>, t_m: u64, entries: &[LogEntry]) {
    let mut e = Enc(buf);
    e.u64(t_m);
    e.u32(entries.len() as u32);
    for le in entries {
        e.u64(le.k);
        e.f32(le.eta);
        e.f32(le.scale);
        e.f32s(&le.u);
        e.f32s(&le.v);
    }
}

fn decode_entries(payload: &[u8]) -> Result<(u64, Vec<LogEntry>), WireError> {
    let mut d = Dec::new(payload);
    let t_m = d.u64()?;
    let n = d.u32()? as usize;
    let mut entries = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        entries.push(LogEntry {
            k: d.u64()?,
            eta: d.f32()?,
            scale: d.f32()?,
            u: Arc::new(d.f32s()?),
            v: Arc::new(d.f32s()?),
        });
    }
    d.finish()?;
    Ok((t_m, entries))
}

impl Wire for MasterMsg {
    fn tag(&self) -> u8 {
        match self {
            MasterMsg::Updates { .. } => TAG_UPDATES,
            MasterMsg::UpdateW { .. } => TAG_UPDATE_W,
            MasterMsg::Stop => TAG_STOP,
        }
    }

    /// O(1)-per-entry closed form, pinned to the codec by property test.
    fn wire_bytes(&self) -> u64 {
        let header = crate::comms::FRAME_HEADER as u64;
        match self {
            MasterMsg::Stop => header,
            MasterMsg::Updates { entries, .. } | MasterMsg::UpdateW { entries, .. } => {
                header + (8 + 4) as u64 + entries.iter().map(|e| e.wire_bytes()).sum::<u64>()
            }
        }
    }

    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            MasterMsg::Stop => {}
            MasterMsg::Updates { t_m, entries } | MasterMsg::UpdateW { t_m, entries } => {
                encode_entries(buf, *t_m, entries);
            }
        }
    }

    fn decode(tag: u8, payload: &[u8]) -> Result<Self, WireError> {
        match tag {
            TAG_STOP => {
                // strict like every other variant: Stop carries no payload
                if !payload.is_empty() {
                    return Err(WireError::Trailing(payload.len()));
                }
                Ok(MasterMsg::Stop)
            }
            TAG_UPDATES => {
                let (t_m, entries) = decode_entries(payload)?;
                Ok(MasterMsg::Updates { t_m, entries })
            }
            TAG_UPDATE_W => {
                let (t_m, entries) = decode_entries(payload)?;
                Ok(MasterMsg::UpdateW { t_m, entries })
            }
            t => Err(WireError::BadTag(t)),
        }
    }
}

// --------------------------------------------------------- SFW-dist

/// Frame tags of the synchronous SFW-dist protocol (Algorithm 1).  The
/// uplink (`DistUp`) and downlink (`DistDown`) are decoded by different
/// types, so their tag spaces are independent; the compressed-gradient
/// tags still avoid the downlink's 1/2/3 to keep hexdumps unambiguous.
pub const TAG_DIST_GRAD: u8 = 1;
pub const TAG_DIST_COMPUTE: u8 = 1;
pub const TAG_DIST_STOP: u8 = 2;
pub const TAG_DIST_COMPUTE_FACTORED: u8 = 3;
pub const TAG_DIST_GRAD_BF16: u8 = 4;
pub const TAG_DIST_GRAD_INT8: u8 = 5;

/// Worker -> master round reply: the dense partial gradient —
/// O(D1 * D2) on the wire, the cost the paper's protocol eliminates.
/// Carries the round index `k` it answers, so the barrier can discard
/// duplicated or straggling frames from earlier rounds instead of
/// folding a stale gradient into the wrong reduction.
///
/// Under `--uplink bf16|int8` the gradient is quantized **once, at
/// construction** ([`DistUp::quantized`]): `grad` holds the dequantized
/// entries and `scales` the per-row int8 scales, so encode -> decode is
/// the identity and the master's reduction is transport-independent.
#[derive(Clone, Debug, PartialEq)]
pub struct DistUp {
    pub worker_id: u32,
    /// Round (master iteration) this reply answers — echoed from
    /// [`DistDown::Compute`].
    pub k: u64,
    /// Minibatch loss telemetry (kept on the wire for parity with Alg 3;
    /// the master reports full-objective loss via the evaluator).
    pub loss_sum: f64,
    pub grad: Mat,
    /// Uplink codec this message is framed with (picks the frame tag).
    pub codec: GradCodec,
    /// One int8 scale per gradient row (empty unless `codec == Int8`).
    pub scales: Vec<f32>,
}

impl DistUp {
    /// Leading payload bytes the chaos layer must not bit-flip:
    /// `worker_id` (reply routing) and `k` (barrier identity).  A
    /// flipped round index would make the barrier wait forever for a
    /// reply that already arrived under the wrong round — the
    /// synchronous protocol has no retransmission to recover with.
    /// Every codec variant shares this prefix, so one guard covers all
    /// three tags.
    pub const CORRUPT_GUARD: usize = 4 + 8;

    /// Uncompressed (f32) reply — the default protocol message, with the
    /// legacy wire layout.
    pub fn dense(worker_id: u32, k: u64, loss_sum: f64, grad: Mat) -> Self {
        Self::quantized(GradCodec::F32, worker_id, k, loss_sum, grad)
    }

    /// Quantize the gradient through `codec` (identity for `F32`).
    /// int8 scales are per row: one f32 of overhead buys each row its
    /// own dynamic range, so a single large entry cannot flatten the
    /// whole matrix to zero.  Callers on the gradient path pair this
    /// with [`crate::linalg::ErrorFeedback`] (compensate before, absorb
    /// the dequantized `grad` after); a non-finite entry poisons its
    /// row's scale to NaN so the master's finite gate still fires.
    pub fn quantized(codec: GradCodec, worker_id: u32, k: u64, loss_sum: f64, mut grad: Mat) -> Self {
        let mut scales = Vec::new();
        match codec {
            GradCodec::F32 => {}
            GradCodec::Bf16 => {
                for x in grad.data.iter_mut() {
                    *x = bf16_truncate(*x);
                }
            }
            GradCodec::Int8 => {
                scales = Vec::with_capacity(grad.rows);
                for r in 0..grad.rows {
                    let row = r * grad.cols..(r + 1) * grad.cols;
                    let s = int8_scale(&grad.data[row.clone()]);
                    for x in &mut grad.data[row] {
                        *x = int8_dequant(int8_quant(*x, s), s);
                    }
                    scales.push(s);
                }
            }
        }
        DistUp { worker_id, k, loss_sum, grad, codec, scales }
    }
}

impl Wire for DistUp {
    fn tag(&self) -> u8 {
        match self.codec {
            GradCodec::F32 => TAG_DIST_GRAD,
            GradCodec::Bf16 => TAG_DIST_GRAD_BF16,
            GradCodec::Int8 => TAG_DIST_GRAD_INT8,
        }
    }

    /// O(1) closed form per codec, pinned to the codec by property test.
    fn wire_bytes(&self) -> u64 {
        let header = crate::comms::FRAME_HEADER as u64 + (4 + 8 + 8 + 4 + 4) as u64;
        let n = self.grad.data.len() as u64;
        match self.codec {
            GradCodec::F32 => header + 4 * n,
            GradCodec::Bf16 => header + 2 * n,
            // one f32 scale per row + 1 byte per entry
            GradCodec::Int8 => header + 4 * self.grad.rows as u64 + n,
        }
    }

    fn encode(&self, buf: &mut Vec<u8>) {
        let mut e = Enc(buf);
        e.u32(self.worker_id);
        e.u64(self.k);
        e.f64(self.loss_sum);
        match self.codec {
            GradCodec::F32 => e.mat(&self.grad),
            GradCodec::Bf16 => {
                e.u32(self.grad.rows as u32);
                e.u32(self.grad.cols as u32);
                for &x in &self.grad.data {
                    e.u16(bf16_bits(x));
                }
            }
            GradCodec::Int8 => {
                e.u32(self.grad.rows as u32);
                e.u32(self.grad.cols as u32);
                for &s in &self.scales {
                    e.f32(s);
                }
                for r in 0..self.grad.rows {
                    let s = self.scales[r];
                    for c in 0..self.grad.cols {
                        e.0.push(int8_quant(self.grad.at(r, c), s) as u8);
                    }
                }
            }
        }
    }

    fn decode(tag: u8, payload: &[u8]) -> Result<Self, WireError> {
        let codec = match tag {
            TAG_DIST_GRAD => GradCodec::F32,
            TAG_DIST_GRAD_BF16 => GradCodec::Bf16,
            TAG_DIST_GRAD_INT8 => GradCodec::Int8,
            t => return Err(WireError::BadTag(t)),
        };
        let mut d = Dec::new(payload);
        let worker_id = d.u32()?;
        let k = d.u64()?;
        let loss_sum = d.f64()?;
        let mut scales = Vec::new();
        let grad = match codec {
            GradCodec::F32 => d.mat()?,
            GradCodec::Bf16 => {
                let rows = d.u32()? as usize;
                let cols = d.u32()? as usize;
                let nb = rows
                    .checked_mul(cols)
                    .and_then(|n| n.checked_mul(2))
                    .ok_or(WireError::Malformed("matrix dims overflow"))?;
                let bytes = d.raw(nb)?;
                let data = bytes
                    .chunks_exact(2)
                    .map(|c| bf16_from_bits(u16::from_le_bytes([c[0], c[1]])))
                    .collect();
                Mat::from_vec(rows, cols, data)
            }
            GradCodec::Int8 => {
                let rows = d.u32()? as usize;
                let cols = d.u32()? as usize;
                let n = rows
                    .checked_mul(cols)
                    .ok_or(WireError::Malformed("matrix dims overflow"))?;
                scales = Vec::with_capacity(rows);
                for _ in 0..rows {
                    scales.push(d.f32()?);
                }
                let bytes = d.raw(n)?;
                let mut data = Vec::with_capacity(n);
                for r in 0..rows {
                    let s = scales[r];
                    for c in 0..cols {
                        data.push(int8_dequant(bytes[r * cols + c] as i8, s));
                    }
                }
                Mat::from_vec(rows, cols, data)
            }
        };
        let msg = DistUp { worker_id, k, loss_sum, grad, codec, scales };
        d.finish()?;
        Ok(msg)
    }
}

/// Master -> worker round broadcast.  The dense variant ships the full
/// iterate plus each worker's minibatch share — O(D1 * D2) per worker
/// per round, `Arc`ed so the local transport's per-worker broadcast is a
/// refcount bump, not W deep copies.  The factored variant ships only
/// the rank-one atoms appended since the previous round — the same
/// [`LogEntry`]s the asynchronous protocol replays — cutting the
/// downlink to O((D1 + D2) * new-atoms) per worker per round (workers
/// reconstruct X locally from the shared-seed X_0; see
/// `coordinator::sync`).
#[derive(Clone, Debug)]
pub enum DistDown {
    Compute { k: u64, m_share: u32, x: Arc<Mat> },
    /// Factored-downlink round: atoms since the last broadcast (0 or 1
    /// in the lockstep barrier protocol; a slice after skipped rounds).
    ComputeFactored { k: u64, m_share: u32, entries: Vec<LogEntry> },
    Stop,
}

impl Wire for DistDown {
    fn tag(&self) -> u8 {
        match self {
            DistDown::Compute { .. } => TAG_DIST_COMPUTE,
            DistDown::ComputeFactored { .. } => TAG_DIST_COMPUTE_FACTORED,
            DistDown::Stop => TAG_DIST_STOP,
        }
    }

    /// O(1)-per-entry closed form, pinned to the codec by property test.
    fn wire_bytes(&self) -> u64 {
        let header = crate::comms::FRAME_HEADER as u64;
        match self {
            DistDown::Stop => header,
            DistDown::Compute { x, .. } => {
                header + (8 + 4 + 4 + 4) as u64 + 4 * x.data.len() as u64
            }
            DistDown::ComputeFactored { entries, .. } => {
                header
                    + (8 + 4 + 4) as u64
                    + entries.iter().map(|e| e.wire_bytes()).sum::<u64>()
            }
        }
    }

    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            DistDown::Stop => {}
            DistDown::Compute { k, m_share, x } => {
                let mut e = Enc(buf);
                e.u64(*k);
                e.u32(*m_share);
                e.mat(x);
            }
            DistDown::ComputeFactored { k, m_share, entries } => {
                let mut e = Enc(buf);
                e.u64(*k);
                e.u32(*m_share);
                e.u32(entries.len() as u32);
                for le in entries {
                    e.u64(le.k);
                    e.f32(le.eta);
                    e.f32(le.scale);
                    e.f32s(&le.u);
                    e.f32s(&le.v);
                }
            }
        }
    }

    fn decode(tag: u8, payload: &[u8]) -> Result<Self, WireError> {
        match tag {
            TAG_DIST_STOP => {
                if !payload.is_empty() {
                    return Err(WireError::Trailing(payload.len()));
                }
                Ok(DistDown::Stop)
            }
            TAG_DIST_COMPUTE => {
                let mut d = Dec::new(payload);
                let msg = DistDown::Compute {
                    k: d.u64()?,
                    m_share: d.u32()?,
                    x: Arc::new(d.mat()?),
                };
                d.finish()?;
                Ok(msg)
            }
            TAG_DIST_COMPUTE_FACTORED => {
                let mut d = Dec::new(payload);
                let k = d.u64()?;
                let m_share = d.u32()?;
                let n = d.u32()? as usize;
                let mut entries = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    entries.push(LogEntry {
                        k: d.u64()?,
                        eta: d.f32()?,
                        scale: d.f32()?,
                        u: Arc::new(d.f32s()?),
                        v: Arc::new(d.f32s()?),
                    });
                }
                d.finish()?;
                Ok(DistDown::ComputeFactored { k, m_share, entries })
            }
            t => Err(WireError::BadTag(t)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::FRAME_HEADER;

    fn entry(k: u64, d1: usize, d2: usize) -> LogEntry {
        LogEntry {
            k,
            eta: 0.5,
            scale: -1.0,
            u: Arc::new(vec![0.0; d1]),
            v: Arc::new(vec![0.0; d2]),
        }
    }

    #[test]
    fn update_msg_is_linear_in_d1_plus_d2() {
        let m = UpdateMsg::dense(0, 10, vec![0.0; 30], vec![0.0; 40], 1.0, 0.0, 64, 0.0);
        // 5-byte frame header + 44-byte payload header + 4*(30+40)
        assert_eq!(m.wire_bytes(), (FRAME_HEADER + 44) as u64 + 280);
        // crucially NOT 4 * 30 * 40 (the dense-gradient cost)
        assert!(m.wire_bytes() < 4 * 30 * 40);
    }

    #[test]
    fn quantized_update_msg_shrinks_and_round_trips_exactly() {
        let u: Vec<f32> = (0..30).map(|i| (i as f32 * 0.37).sin() * 0.4).collect();
        let v: Vec<f32> = (0..40).map(|i| (i as f32 * 0.23).cos() * 0.3).collect();
        let f32_bytes =
            UpdateMsg::dense(2, 9, u.clone(), v.clone(), 1.5, 0.25, 64, 0.5).wire_bytes();
        for codec in [GradCodec::Bf16, GradCodec::Int8] {
            let m = UpdateMsg::quantized(codec, 2, 9, u.clone(), v.clone(), 1.5, 0.25, 64, 0.5);
            // quantize-once: the struct already holds dequantized values,
            // so encode -> decode is the identity
            let mut buf = Vec::new();
            m.encode(&mut buf);
            let d = UpdateMsg::decode(m.tag(), &buf).unwrap();
            assert_eq!(d, m);
            // compressed variants are strictly smaller than f32
            assert!(m.wire_bytes() < f32_bytes, "{codec:?} did not shrink");
            // quantization error stays far inside the sane_rank_one gate
            let err: f32 = m.u.iter().zip(&u).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
            assert!(err < 0.4 / 127.0 + 1e-3, "{codec:?} error {err}");
        }
        // closed forms: bf16 halves the vector bytes; int8 quarters them
        // (plus two f32 scales)
        let bf = UpdateMsg::quantized(GradCodec::Bf16, 2, 9, u.clone(), v.clone(), 1.5, 0.25, 64, 0.5);
        assert_eq!(bf.wire_bytes(), (FRAME_HEADER + 44) as u64 + 2 * 70);
        let i8m = UpdateMsg::quantized(GradCodec::Int8, 2, 9, u, v, 1.5, 0.25, 64, 0.5);
        assert_eq!(i8m.wire_bytes(), (FRAME_HEADER + 44) as u64 + 8 + 70);
    }

    #[test]
    fn master_msg_bytes_scale_with_entry_count() {
        let one = MasterMsg::Updates { t_m: 1, entries: vec![entry(1, 30, 40)] };
        let three = MasterMsg::Updates {
            t_m: 3,
            entries: vec![entry(1, 30, 40), entry(2, 30, 40), entry(3, 30, 40)],
        };
        // the per-entry cost the simulator uses matches the real codec
        let per_entry = entry(0, 30, 40).wire_bytes();
        assert_eq!(three.wire_bytes() - one.wire_bytes(), 2 * per_entry);
        // Stop is a bare frame header
        assert_eq!(MasterMsg::Stop.wire_bytes(), FRAME_HEADER as u64);
    }

    #[test]
    fn asyn_codec_round_trips() {
        let m = UpdateMsg::dense(3, 17, vec![1.0, -2.5, 3.25], vec![0.5, 4.0], 6.5, 2.25, 99, 0.125);
        let mut buf = Vec::new();
        m.encode(&mut buf);
        let d = UpdateMsg::decode(m.tag(), &buf).unwrap();
        assert_eq!((d.worker_id, d.t_w, d.m), (3, 17, 99));
        assert_eq!(d.u, m.u);
        assert_eq!(d.v, m.v);

        let msg = MasterMsg::Updates { t_m: 5, entries: vec![entry(5, 2, 1)] };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        match MasterMsg::decode(msg.tag(), &buf).unwrap() {
            MasterMsg::Updates { t_m, entries } => {
                assert_eq!(t_m, 5);
                assert_eq!(entries.len(), 1);
                assert_eq!(entries[0].k, 5);
            }
            _ => panic!("wrong variant"),
        }
        assert!(matches!(MasterMsg::decode(TAG_STOP, &[]).unwrap(), MasterMsg::Stop));
        assert!(MasterMsg::decode(77, &[]).is_err());
        // a garbage payload under a Stop tag is corruption, not a Stop
        assert!(MasterMsg::decode(TAG_STOP, &[1]).is_err());
        assert!(DistDown::decode(TAG_DIST_STOP, &[1]).is_err());
    }

    #[test]
    fn dist_messages_cost_d1_times_d2() {
        let x = Mat::zeros(30, 40);
        let down = DistDown::Compute { k: 1, m_share: 16, x: Arc::new(x.clone()) };
        let up = DistUp::dense(0, 1, 0.0, x);
        // both directions carry the dense matrix: >= 4 * D1 * D2 bytes
        assert!(down.wire_bytes() >= 4 * 30 * 40);
        assert!(up.wire_bytes() >= 4 * 30 * 40);
        assert_eq!(DistDown::Stop.wire_bytes(), FRAME_HEADER as u64);
    }

    #[test]
    fn quantized_dist_up_shrinks_and_round_trips_exactly() {
        let mut rng = crate::util::rng::Rng::new(7);
        let g = Mat::randn(30, 40, 0.8, &mut rng);
        let f32_bytes = DistUp::dense(1, 4, 0.5, g.clone()).wire_bytes();
        for codec in [GradCodec::Bf16, GradCodec::Int8] {
            let up = DistUp::quantized(codec, 1, 4, 0.5, g.clone());
            let mut buf = Vec::new();
            up.encode(&mut buf);
            let d = DistUp::decode(up.tag(), &buf).unwrap();
            assert_eq!(d, up, "{codec:?} encode/decode is not the identity");
            assert!(up.wire_bytes() < f32_bytes, "{codec:?} did not shrink");
        }
        // closed forms: bf16 = 2 B/entry; int8 = 1 B/entry + 4 B/row.
        // The int8 uplink is a >= 3.6x byte win over f32 at this shape —
        // the ratio check_smoke_bytes.py asserts end-to-end.
        let bf = DistUp::quantized(GradCodec::Bf16, 1, 4, 0.5, g.clone());
        assert_eq!(bf.wire_bytes(), (FRAME_HEADER + 28) as u64 + 2 * 1200);
        let i8m = DistUp::quantized(GradCodec::Int8, 1, 4, 0.5, g.clone());
        assert_eq!(i8m.wire_bytes(), (FRAME_HEADER + 28) as u64 + 4 * 30 + 1200);
        assert!(f32_bytes as f64 / i8m.wire_bytes() as f64 > 3.6);
    }

    #[test]
    fn quantized_dist_up_poisons_non_finite_rows() {
        // A worker that hits a non-finite gradient ships NaN under every
        // codec, so the master's finite gate fires transport- and
        // codec-independently.
        let mut g = Mat::zeros(4, 3);
        g.data[5] = f32::INFINITY;
        for codec in GradCodec::ALL {
            let up = DistUp::quantized(*codec, 0, 1, 0.0, g.clone());
            assert!(
                up.grad.data.iter().any(|x| !x.is_finite()),
                "{codec:?} lost the poison marker"
            );
            // ...and only the poisoned row, for the scaled codec
            if *codec == GradCodec::Int8 {
                assert!(up.grad.data[3..6].iter().all(|x| x.is_nan()));
                assert!(up.grad.data[..3].iter().all(|x| x.is_finite()));
            }
        }
    }

    #[test]
    fn factored_dist_downlink_costs_d1_plus_d2() {
        // One new atom per round: the factored broadcast is linear in
        // D1 + D2 where the dense broadcast is D1 * D2 — the tentpole's
        // whole point, on the wire.
        let factored =
            DistDown::ComputeFactored { k: 5, m_share: 16, entries: vec![entry(5, 30, 40)] };
        let dense =
            DistDown::Compute { k: 5, m_share: 16, x: Arc::new(Mat::zeros(30, 40)) };
        assert!(factored.wire_bytes() < 8 * 4 * (30 + 40));
        assert!(dense.wire_bytes() >= 4 * 30 * 40);
        assert!(factored.wire_bytes() * 4 < dense.wire_bytes());
        // an empty round ships a near-bare frame
        let empty = DistDown::ComputeFactored { k: 6, m_share: 16, entries: Vec::new() };
        assert_eq!(empty.wire_bytes(), (FRAME_HEADER + 8 + 4 + 4) as u64);
    }

    #[test]
    fn factored_dist_downlink_round_trips() {
        let msg = DistDown::ComputeFactored {
            k: 9,
            m_share: 8,
            entries: vec![entry(9, 3, 2), entry(10, 3, 2)],
        };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        match DistDown::decode(msg.tag(), &buf).unwrap() {
            DistDown::ComputeFactored { k, m_share, entries } => {
                assert_eq!((k, m_share), (9, 8));
                assert_eq!(entries.len(), 2);
                assert_eq!(entries[1].k, 10);
                assert_eq!(entries[0].u.len(), 3);
                assert_eq!(entries[0].v.len(), 2);
            }
            other => panic!("wrong variant {other:?}"),
        }
        // truncation errors, never panics
        assert!(DistDown::decode(TAG_DIST_COMPUTE_FACTORED, &buf[..buf.len() - 2]).is_err());
    }

    #[test]
    fn truncated_frames_error_not_panic() {
        let m = UpdateMsg::dense(1, 2, vec![1.0; 4], vec![1.0; 4], 0.0, 0.0, 1, 0.0);
        let mut buf = Vec::new();
        m.encode(&mut buf);
        assert!(UpdateMsg::decode(m.tag(), &buf[..buf.len() - 3]).is_err());
        let mut extended = buf.clone();
        extended.push(0);
        assert!(UpdateMsg::decode(m.tag(), &extended).is_err());
        // same contract for the compressed spellings
        for codec in [GradCodec::Bf16, GradCodec::Int8] {
            let m = UpdateMsg::quantized(codec, 1, 2, vec![1.0; 4], vec![1.0; 4], 0.0, 0.0, 1, 0.0);
            let mut buf = Vec::new();
            m.encode(&mut buf);
            assert!(UpdateMsg::decode(m.tag(), &buf[..buf.len() - 1]).is_err());
            let up = DistUp::quantized(codec, 1, 2, 0.0, Mat::zeros(3, 3));
            let mut buf = Vec::new();
            up.encode(&mut buf);
            assert!(DistUp::decode(up.tag(), &buf[..buf.len() - 1]).is_err());
            let mut extended = buf.clone();
            extended.push(0);
            assert!(DistUp::decode(up.tag(), &extended).is_err());
        }
    }
}
