//! Wire messages of the SFW-asyn protocol (Algorithm 3) and their byte
//! accounting.
//!
//! The entire point of the paper's communication design is visible in the
//! types: a worker sends `{u, v, t_w}` — O(D1 + D2) floats — and the master
//! replies with the update-log slice `{(u_k, v_k)} k = t_w+1..t_m` — again
//! O(D1 + D2) per entry — instead of gradient/parameter matrices of size
//! O(D1 * D2).  `wire_bytes()` on each type is what the comm-cost bench
//! measures, and the TCP transport serializes exactly these layouts.

use std::sync::Arc;

/// Rank-one LMO result sent worker -> master: `{u_w, v_w, t_w}` plus the
/// minibatch loss ride-along (f32 telemetry, negligible on the wire).
#[derive(Clone, Debug)]
pub struct UpdateMsg {
    pub worker_id: u32,
    /// Iteration of the model copy the update was computed against.
    pub t_w: u64,
    pub u: Vec<f32>,
    pub v: Vec<f32>,
    pub sigma: f32,
    pub loss_sum: f64,
    /// True minibatch size used.
    pub m: u32,
}

impl UpdateMsg {
    /// Serialized size: header (id 4 + t_w 8 + sigma 4 + loss 8 + m 4 +
    /// two u32 lengths) + payload vectors.
    pub fn wire_bytes(&self) -> u64 {
        (4 + 8 + 4 + 8 + 4 + 4 + 4) as u64 + 4 * (self.u.len() + self.v.len()) as u64
    }
}

/// One entry of the master's update log: iterate recursion Eqn (6)
/// `X_k = (1 - eta_k) X_{k-1} + eta_k * scale * u_k v_k^T`
/// (`scale = -theta` for the nuclear-ball LMO direction).  `Arc`ed so the
/// master can hand log slices to workers without copying the vectors.
#[derive(Clone, Debug)]
pub struct LogEntry {
    /// Master iteration k this entry produced (1-based).
    pub k: u64,
    pub eta: f32,
    pub scale: f32,
    pub u: Arc<Vec<f32>>,
    pub v: Arc<Vec<f32>>,
}

impl LogEntry {
    pub fn wire_bytes(&self) -> u64 {
        (8 + 4 + 4 + 4 + 4) as u64 + 4 * (self.u.len() + self.v.len()) as u64
    }
}

/// Master -> worker reply.
#[derive(Clone, Debug)]
pub enum MasterMsg {
    /// Catch-up slice: everything the worker missed, `t_w+1 ..= t_m`.
    Updates { t_m: u64, entries: Vec<LogEntry> },
    /// SVRF epoch boundary (Algorithm 5's update-W signal): replay to
    /// `t_m`, snapshot W, recompute the full gradient at W.
    UpdateW { t_m: u64, entries: Vec<LogEntry> },
    Stop,
}

impl MasterMsg {
    pub fn wire_bytes(&self) -> u64 {
        match self {
            MasterMsg::Updates { entries, .. } | MasterMsg::UpdateW { entries, .. } => {
                (8 + 4 + 1) as u64 + entries.iter().map(|e| e.wire_bytes()).sum::<u64>()
            }
            MasterMsg::Stop => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(k: u64, d1: usize, d2: usize) -> LogEntry {
        LogEntry {
            k,
            eta: 0.5,
            scale: -1.0,
            u: Arc::new(vec![0.0; d1]),
            v: Arc::new(vec![0.0; d2]),
        }
    }

    #[test]
    fn update_msg_is_linear_in_d1_plus_d2() {
        let m = UpdateMsg {
            worker_id: 0,
            t_w: 10,
            u: vec![0.0; 30],
            v: vec![0.0; 40],
            sigma: 1.0,
            loss_sum: 0.0,
            m: 64,
        };
        // 36-byte header + 4*(30+40)
        assert_eq!(m.wire_bytes(), 36 + 280);
        // crucially NOT 4 * 30 * 40 (the dense-gradient cost)
        assert!(m.wire_bytes() < 4 * 30 * 40);
    }

    #[test]
    fn master_msg_bytes_scale_with_entry_count() {
        let one = MasterMsg::Updates { t_m: 1, entries: vec![entry(1, 30, 40)] };
        let three = MasterMsg::Updates {
            t_m: 3,
            entries: vec![entry(1, 30, 40), entry(2, 30, 40), entry(3, 30, 40)],
        };
        let per_entry = entry(0, 30, 40).wire_bytes();
        assert_eq!(three.wire_bytes() - one.wire_bytes(), 2 * per_entry);
        assert_eq!(MasterMsg::Stop.wire_bytes(), 1);
    }
}
