//! The SFW-asyn worker loop (Algorithm 3, lines 14–23).
//!
//! Each worker keeps a local X (dense or factored, matching the run's
//! representation) it advances ONLY by replaying the master's rank-one
//! log slices (Eqn 6) — it never receives a parameter matrix.  In
//! factored mode a replayed entry becomes an atom of the local iterate
//! outright.  Per cycle it samples a minibatch of the schedule size for
//! its current sync point, runs the fused gradient->LMO step (native
//! math or the AOT JAX/Pallas artifact via PJRT), ships `{u, v, t_w}`,
//! and blocks on the master's catch-up reply.

use std::time::Duration;

use crate::algo::engine::StepEngine;
use crate::algo::schedule::BatchSchedule;
use crate::comms::{GradCodec, WorkerLink};
use crate::coordinator::messages::{MasterMsg, UpdateMsg};
use crate::coordinator::update_log::replay_after;
use crate::linalg::{Iterate, Repr};
use crate::metrics::Counters;
use crate::util::rng::Rng;

/// Injected straggler model (Assumption 3): a task of `units` work whose
/// nominal time is `unit * units` completes in `unit * units * geometric(p)`
/// — the worker sleeps the excess `unit * units * (geometric(p) - 1)`.
/// p = 1 disables it; small p produces the heavy-tailed heterogeneity of a
/// real multi-tenant cluster.  Scaling by the assigned work is what lets
/// the synchronous baseline profit from splitting batches across workers
/// (as on EC2) while still paying the max-of-W tail at its barrier.
#[derive(Clone, Copy, Debug)]
pub struct Straggler {
    /// Nominal time per unit of work (e.g. per gradient sample).
    pub unit: Duration,
    pub p: f64,
}

impl Straggler {
    /// Sleep the straggler excess for a task of `units` work.
    pub fn sleep(&self, rng: &mut Rng, units: u64) {
        let mult = rng.geometric(self.p) - 1;
        if mult > 0 {
            let ns = self.unit.as_nanos() as u64 * units * mult;
            std::thread::sleep(Duration::from_nanos(ns));
        }
    }
}

pub struct WorkerOptions {
    pub worker_id: u32,
    pub batch: BatchSchedule,
    pub seed: u64,
    pub straggler: Option<Straggler>,
    /// Local iterate representation (must match the master's so the
    /// shared-seed X_0 and every replayed slice land on the same model).
    pub repr: Repr,
    /// Uplink codec for the `{u, v}` atoms.  Quantized plainly (no error
    /// feedback): the atoms are unit directions gated by the master's
    /// `sane_rank_one` check, and the per-entry error stays far inside
    /// that gate's norm window.
    pub uplink: GradCodec,
}

/// Run the worker loop until the master says Stop (or disconnects).
pub fn run_worker<L: WorkerLink<UpdateMsg, MasterMsg> + ?Sized, E: StepEngine + ?Sized>(
    link: &mut L,
    engine: &mut E,
    opts: &WorkerOptions,
    counters: &Counters,
) {
    let obj = engine.objective().clone();
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let n = obj.n();
    // X_0 from the shared seed (stands in for the {u_0, v_0} broadcast).
    let mut x = Iterate::init_rank_one(opts.repr, d1, d2, theta, &mut Rng::new(opts.seed));
    let mut t_w = 0u64;
    let mut rng = Rng::new(opts.seed ^ 0xD1F7).fork(opts.worker_id as u64 + 1);
    let mut idx: Vec<usize> = Vec::new();

    loop {
        // Alg 3 line 20: |S| = m_{t_w} (schedule indexed by the sync point).
        let m = opts.batch.m(t_w.max(1));
        rng.sample_indices(n, m, &mut idx);
        let out = engine.step_it(&x, &idx);
        counters.add_grad_evals(m as u64);
        counters.add_lmo();
        if let Some(s) = &opts.straggler {
            s.sleep(&mut rng, m as u64);
        }
        link.send(UpdateMsg::quantized(
            opts.uplink,
            opts.worker_id,
            t_w,
            out.u,
            out.v,
            out.sigma,
            out.loss_sum,
            m as u32,
            out.gap,
        ));
        match link.recv() {
            Some(MasterMsg::Updates { entries, .. }) => {
                // Idempotent, gap-tolerant replay: resync slices may
                // overlap entries already applied, and a gapped slice
                // (the echo of a corrupted t_w claim) applies nothing.
                // t_w advances only as far as entries were actually
                // applied — never to the reply's t_m blindly — so the
                // next claim is always this iterate's true version.
                t_w = replay_after(&mut x, &entries, t_w);
            }
            Some(MasterMsg::UpdateW { .. }) => {
                // Plain SFW-asyn masters never send UpdateW (it belongs to
                // the SVRF epoch protocol).  Tolerate rather than crash:
                // ignore it and resubmit at the unchanged t_w.
                eprintln!("worker {}: ignoring unexpected UpdateW", opts.worker_id);
            }
            Some(MasterMsg::Stop) | None => return,
        }
    }
}
