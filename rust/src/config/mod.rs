//! Typed experiment configuration: an INI-subset config file (`key = value`
//! lines, `[section]` headers, `#`/`;` comments) merged with CLI overrides
//! (`--section.key value` or `--key value`).  TOML/serde are not in the
//! offline crate set; this covers what a launcher actually needs.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::cli::Args;

/// Flat key-value store with `section.key` naming.
#[derive(Debug, Default, Clone)]
pub struct Config {
    values: BTreeMap<String, String>,
}

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("parse error at line {line}: {msg}")]
    Parse { line: usize, msg: String },
    #[error("key '{0}': cannot parse value '{1}'")]
    BadValue(String, String),
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse INI-subset text.
    pub fn from_str(text: &str) -> Result<Self, ConfigError> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or(ConfigError::Parse {
                    line: lineno + 1,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or(ConfigError::Parse {
                line: lineno + 1,
                msg: format!("expected 'key = value', got '{line}'"),
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            cfg.values.insert(key, v.trim().to_string());
        }
        Ok(cfg)
    }

    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<Self, ConfigError> {
        Self::from_str(&std::fs::read_to_string(path)?)
    }

    /// Overlay CLI flags: every `--k v` becomes `k = v` (dots allowed).
    pub fn merge_args(&mut self, args: &Args) {
        for key in self.values.keys().cloned().collect::<Vec<_>>() {
            if let Some(v) = args.get_opt(&key) {
                self.values.insert(key, v);
            }
        }
        // also accept new keys not present in the file
        // (Args doesn't expose iteration; callers set known keys explicitly)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get_opt(&self, key: &str) -> Option<String> {
        self.values.get(key).cloned()
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.values.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, ConfigError> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ConfigError::BadValue(key.to_string(), v.clone())),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

/// Fully-typed training configuration used by the launcher (`sfw train`).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// "matrix_sensing" | "pnn".
    pub task: String,
    /// Algorithm name resolved against `session::registry()` ("sfw",
    /// "sfw-asyn", "svrf-asyn", "sfw-dist", "sva", "dfw-power", "pgd").
    pub algo: String,
    pub workers: usize,
    /// Kernel-pool threads per process (>= 1; see
    /// `linalg::kernels` — results are bit-identical for any value).
    pub threads: usize,
    pub tau: u64,
    pub iterations: u64,
    /// Constant minibatch size; 0 = the algorithm's theorem schedule
    /// (from `batch_scale`/`batch_cap`/`tau`).
    pub batch: usize,
    pub batch_cap: usize,
    pub batch_scale: f64,
    pub power_iters: usize,
    /// Iterate representation: "auto" | "dense" | "factored" (auto =
    /// per-objective default; see `session::ReprKind`).
    pub repr: String,
    /// Uplink gradient codec: "f32" | "bf16" | "int8" (see
    /// `comms::GradCodec`; lossy codecs require a solver with a
    /// compressible uplink).
    pub uplink: String,
    pub theta: f32,
    pub seed: u64,
    pub eval_every: u64,
    /// Dual-gap stopping tolerance; 0 disables (see `TrainSpec::tol`).
    pub tol: f64,
    /// Step-size policy: "vanilla" | "analytic" | "line-search" |
    /// "armijo" | "away" | "pairwise" (see `algo::schedule::StepMethod`).
    pub step: String,
    /// "native" | "pjrt".
    pub engine: String,
    /// "local" | "tcp".
    pub transport: String,
    /// TCP master bind address (`host:port`); empty = loopback ephemeral.
    pub tcp_bind: String,
    /// TCP: await external `sfw worker` processes instead of spawning
    /// worker threads.
    pub tcp_await: bool,
    /// SVRF-asyn outer epochs; 0 = derive from `iterations`.
    pub epochs: u32,
    pub artifacts_dir: String,
    // dataset
    pub ms_n: usize,
    pub ms_d: usize,
    pub ms_rank: usize,
    pub ms_noise: f32,
    pub pnn_n: usize,
    pub pnn_d: usize,
    // synthetic recommender (task = sparse_completion)
    pub rec_rows: usize,
    pub rec_cols: usize,
    pub rec_rank: usize,
    /// Target fraction of observed entries (nnz / (rows * cols)).
    pub rec_density: f64,
    /// Power-law exponent of the per-row observation counts.
    pub rec_alpha: f64,
    /// Fraction of observed entries held out for evaluation.
    pub rec_holdout: f64,
    /// Observation noise as a fraction of the clean-entry RMS.
    pub rec_noise: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            task: "matrix_sensing".into(),
            algo: "sfw-asyn".into(),
            workers: 4,
            threads: 1,
            tau: 8,
            iterations: 300,
            batch: 0,
            batch_cap: 10_000,
            batch_scale: 0.5,
            power_iters: 24,
            repr: "auto".into(),
            uplink: "f32".into(),
            theta: 1.0,
            seed: 42,
            eval_every: 10,
            tol: 0.0,
            step: "vanilla".into(),
            engine: "native".into(),
            transport: "local".into(),
            tcp_bind: String::new(),
            tcp_await: false,
            epochs: 0,
            artifacts_dir: "artifacts".into(),
            ms_n: 90_000,
            ms_d: 30,
            ms_rank: 3,
            ms_noise: 0.1,
            pnn_n: 60_000,
            pnn_d: 196,
            rec_rows: 2000,
            rec_cols: 400,
            rec_rank: 4,
            rec_density: 0.01,
            rec_alpha: 1.1,
            rec_holdout: 0.1,
            rec_noise: 0.05,
        }
    }
}

impl TrainConfig {
    /// Build from optional config file + CLI overrides.
    pub fn load(args: &Args) -> Result<Self, ConfigError> {
        let cfg = if let Some(path) = args.get_opt("config") {
            Config::from_file(path)?
        } else {
            Config::new()
        };
        Self::resolve(cfg, args)
    }

    /// Resolve an already-parsed file config + CLI overrides — for
    /// callers that also read other sections of the same file (e.g. the
    /// sweep harness's `[sweep]`) and must not parse it twice.
    pub fn resolve(mut cfg: Config, args: &Args) -> Result<Self, ConfigError> {
        // Launcher keys by owning section: `[train]` groups run knobs,
        // `[data]` groups dataset knobs.  A key in the WRONG section is
        // ignored (not silently honored).
        const TRAIN_KEYS: &[&str] = &[
            "task", "algo", "engine", "transport", "tcp-bind", "tcp-await",
            "artifacts-dir", "workers", "threads", "tau", "iterations", "epochs", "batch",
            "batch-cap", "batch-scale", "power-iters", "repr", "uplink", "theta",
            "seed", "eval-every", "tol", "step",
        ];
        const DATA_KEYS: &[&str] = &[
            "ms-n", "ms-d", "ms-rank", "ms-noise", "pnn-n", "pnn-d", "rec-rows",
            "rec-cols", "rec-rank", "rec-density", "rec-alpha", "rec-holdout",
            "rec-noise",
        ];

        // 1. Promote file-sectioned keys to their flat names (a flat
        //    entry in the file wins over a sectioned one).
        for (keys, section) in [(TRAIN_KEYS, "train"), (DATA_KEYS, "data")] {
            for key in keys {
                if cfg.get_opt(key).is_none() {
                    if let Some(v) = cfg.get_opt(&format!("{section}.{key}")) {
                        cfg.set(key, &v);
                    }
                }
            }
        }
        // 2. CLI flags override file values.  Sectioned spellings
        //    (`--train.workers 8`, `--data.ms-n 90000`) are accepted for
        //    the owning section; the flat spelling wins when both are
        //    given.
        for (keys, section) in [(TRAIN_KEYS, "train"), (DATA_KEYS, "data")] {
            for key in keys {
                for cand in [format!("{section}.{key}"), (*key).to_string()] {
                    if let Some(v) = args.get_opt(&cand) {
                        cfg.set(key, &v);
                    }
                }
            }
        }
        // Bare `--tcp-await` (boolean flag spelling) counts as true.  An
        // explicit value was already resolved above and must keep going
        // through the bool parse so typos ("--tcp-await no") error
        // instead of silently awaiting workers that never come.
        let is_bare = |key: &str| args.has(key) && args.get_opt(key).is_none();
        if is_bare("tcp-await") || is_bare("train.tcp-await") {
            cfg.set("tcp-await", "true");
        }
        let d = TrainConfig::default();
        Ok(TrainConfig {
            task: cfg.get_str("task", &d.task),
            algo: cfg.get_str("algo", &d.algo),
            workers: cfg.get("workers", d.workers)?,
            threads: cfg.get("threads", d.threads)?,
            tau: cfg.get("tau", d.tau)?,
            iterations: cfg.get("iterations", d.iterations)?,
            batch: cfg.get("batch", d.batch)?,
            batch_cap: cfg.get("batch-cap", d.batch_cap)?,
            batch_scale: cfg.get("batch-scale", d.batch_scale)?,
            power_iters: cfg.get("power-iters", d.power_iters)?,
            repr: cfg.get_str("repr", &d.repr),
            uplink: cfg.get_str("uplink", &d.uplink),
            theta: cfg.get("theta", d.theta)?,
            seed: cfg.get("seed", d.seed)?,
            eval_every: cfg.get("eval-every", d.eval_every)?,
            tol: cfg.get("tol", d.tol)?,
            step: cfg.get_str("step", &d.step),
            engine: cfg.get_str("engine", &d.engine),
            transport: cfg.get_str("transport", &d.transport),
            tcp_bind: cfg.get_str("tcp-bind", &d.tcp_bind),
            tcp_await: cfg.get("tcp-await", d.tcp_await)?,
            epochs: cfg.get("epochs", d.epochs)?,
            artifacts_dir: cfg.get_str("artifacts-dir", &d.artifacts_dir),
            ms_n: cfg.get("ms-n", d.ms_n)?,
            ms_d: cfg.get("ms-d", d.ms_d)?,
            ms_rank: cfg.get("ms-rank", d.ms_rank)?,
            ms_noise: cfg.get("ms-noise", d.ms_noise)?,
            pnn_n: cfg.get("pnn-n", d.pnn_n)?,
            pnn_d: cfg.get("pnn-d", d.pnn_d)?,
            rec_rows: cfg.get("rec-rows", d.rec_rows)?,
            rec_cols: cfg.get("rec-cols", d.rec_cols)?,
            rec_rank: cfg.get("rec-rank", d.rec_rank)?,
            rec_density: cfg.get("rec-density", d.rec_density)?,
            rec_alpha: cfg.get("rec-alpha", d.rec_alpha)?,
            rec_holdout: cfg.get("rec-holdout", d.rec_holdout)?,
            rec_noise: cfg.get("rec-noise", d.rec_noise)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let text = "
# comment
top = 1
[train]
workers = 8
tau = 4
; another comment
[data]
n = 90000
";
        let c = Config::from_str(text).unwrap();
        assert_eq!(c.get::<usize>("top", 0).unwrap(), 1);
        assert_eq!(c.get::<usize>("train.workers", 0).unwrap(), 8);
        assert_eq!(c.get::<u64>("train.tau", 0).unwrap(), 4);
        assert_eq!(c.get::<usize>("data.n", 0).unwrap(), 90_000);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::from_str("novalue\n").is_err());
        assert!(Config::from_str("[unterminated\n").is_err());
    }

    #[test]
    fn defaults_and_bad_values() {
        let c = Config::from_str("x = abc\n").unwrap();
        assert_eq!(c.get::<usize>("missing", 7).unwrap(), 7);
        assert!(c.get::<usize>("x", 0).is_err());
    }

    #[test]
    fn train_config_from_cli() {
        let args = Args::parse_from(
            "--task pnn --workers 15 --tau 6 --engine pjrt"
                .split_whitespace()
                .map(String::from),
        );
        let tc = TrainConfig::load(&args).unwrap();
        assert_eq!(tc.task, "pnn");
        assert_eq!(tc.workers, 15);
        assert_eq!(tc.tau, 6);
        assert_eq!(tc.engine, "pjrt");
        assert_eq!(tc.iterations, 300); // default survives
        assert_eq!(tc.transport, "local"); // new default
        assert_eq!(tc.uplink, "f32"); // uncompressed default
        assert_eq!(tc.step, "vanilla");
        assert_eq!(tc.tol, 0.0); // gap stopping off by default
    }

    #[test]
    fn tol_and_step_resolve_from_cli_and_file() {
        let args = Args::parse_from(
            "--tol 1e-3 --step line-search".split_whitespace().map(String::from),
        );
        let tc = TrainConfig::load(&args).unwrap();
        assert!((tc.tol - 1e-3).abs() < 1e-12);
        assert_eq!(tc.step, "line-search");
        let cfg = Config::from_str("[train]\ntol = 0.5\nstep = away\n").unwrap();
        let tc =
            TrainConfig::resolve(cfg, &Args::parse_from(std::iter::empty::<String>())).unwrap();
        assert!((tc.tol - 0.5).abs() < 1e-12);
        assert_eq!(tc.step, "away");
        // a non-numeric tol errors instead of silently never stopping
        let bad = Args::parse_from("--tol soon".split_whitespace().map(String::from));
        assert!(matches!(
            TrainConfig::load(&bad),
            Err(ConfigError::BadValue(k, _)) if k == "tol"
        ));
    }

    #[test]
    fn threads_key_resolves_from_cli_and_file() {
        let args =
            Args::parse_from("--threads 4".split_whitespace().map(String::from));
        assert_eq!(TrainConfig::load(&args).unwrap().threads, 4);
        let cfg = Config::from_str("[train]\nthreads = 2\n").unwrap();
        let tc = TrainConfig::resolve(cfg, &Args::parse_from(std::iter::empty::<String>())).unwrap();
        assert_eq!(tc.threads, 2);
        // default stays single-threaded (determinism makes this safe to
        // raise, but opt-in keeps laptops predictable)
        let tc = TrainConfig::load(&Args::parse_from(std::iter::empty::<String>())).unwrap();
        assert_eq!(tc.threads, 1);
    }

    #[test]
    fn uplink_key_resolves_from_cli_and_file() {
        let args =
            Args::parse_from("--uplink int8".split_whitespace().map(String::from));
        assert_eq!(TrainConfig::load(&args).unwrap().uplink, "int8");
        let cfg = Config::from_str("[train]\nuplink = bf16\n").unwrap();
        let tc = TrainConfig::resolve(cfg, &Args::parse_from(std::iter::empty::<String>())).unwrap();
        assert_eq!(tc.uplink, "bf16");
    }

    #[test]
    fn sectioned_cli_overrides_resolve() {
        let args = Args::parse_from(
            "--train.workers 9 --data.ms-n 1234 --transport tcp"
                .split_whitespace()
                .map(String::from),
        );
        let tc = TrainConfig::load(&args).unwrap();
        assert_eq!(tc.workers, 9);
        assert_eq!(tc.ms_n, 1234);
        assert_eq!(tc.transport, "tcp");
    }

    #[test]
    fn wrong_section_keys_are_ignored() {
        // `workers` belongs to [train]; a [data]-spelled override must
        // not leak into the training config (and vice versa).
        let args = Args::parse_from(
            "--data.workers 2 --train.ms-n 10".split_whitespace().map(String::from),
        );
        let tc = TrainConfig::load(&args).unwrap();
        assert_eq!(tc.workers, TrainConfig::default().workers);
        assert_eq!(tc.ms_n, TrainConfig::default().ms_n);
    }

    #[test]
    fn flat_cli_beats_sectioned() {
        let args = Args::parse_from(
            "--train.workers 9 --workers 3".split_whitespace().map(String::from),
        );
        let tc = TrainConfig::load(&args).unwrap();
        assert_eq!(tc.workers, 3);
    }

    #[test]
    fn tcp_await_accepts_bare_flag_but_rejects_typos() {
        let load = |s: &str| TrainConfig::load(&Args::parse_from(s.split_whitespace().map(String::from)));
        assert!(load("--tcp-await").unwrap().tcp_await); // bare boolean spelling
        assert!(load("--tcp-await true").unwrap().tcp_await);
        assert!(!load("--tcp-await false").unwrap().tcp_await);
        assert!(!load("").unwrap().tcp_await);
        // a typo must error, not silently await workers that never come
        assert!(matches!(
            load("--tcp-await no"),
            Err(ConfigError::BadValue(k, _)) if k == "tcp-await"
        ));
    }

    #[test]
    fn recommender_keys_resolve_from_cli_and_file() {
        let args = Args::parse_from(
            "--task sparse_completion --rec-rows 5000 --data.rec-density 0.02"
                .split_whitespace()
                .map(String::from),
        );
        let tc = TrainConfig::load(&args).unwrap();
        assert_eq!(tc.task, "sparse_completion");
        assert_eq!(tc.rec_rows, 5000);
        assert!((tc.rec_density - 0.02).abs() < 1e-12);
        assert_eq!(tc.rec_cols, TrainConfig::default().rec_cols);
        let cfg = Config::from_str("[data]\nrec-cols = 77\n").unwrap();
        let tc =
            TrainConfig::resolve(cfg, &Args::parse_from(std::iter::empty::<String>())).unwrap();
        assert_eq!(tc.rec_cols, 77);
    }

    #[test]
    fn bad_cli_value_is_a_config_error() {
        let args = Args::parse_from("--workers abc".split_whitespace().map(String::from));
        match TrainConfig::load(&args) {
            Err(ConfigError::BadValue(k, v)) => {
                assert_eq!(k, "workers");
                assert_eq!(v, "abc");
            }
            other => panic!("expected BadValue, got {other:?}"),
        }
    }
}
