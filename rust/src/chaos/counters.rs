//! Per-event chaos accounting, mirroring the structure of
//! [`crate::metrics::Counters`]: one atomic per injected-event kind,
//! bumped by the [`ChaosWorker`](crate::chaos::ChaosWorker) wrappers and
//! snapshotted into every [`Report`](crate::session::Report) and sweep
//! cell.  Because fault decisions are a pure function of
//! `(plan seed, rank, message index)` (see [`crate::chaos`]), these
//! counters are the replay witness: two runs of the same plan on the
//! same protocol schedule must produce *identical* snapshots.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared, thread-safe fault-injection event counters (one per run; all
/// rank wrappers of a run share one instance via `Arc`).
#[derive(Debug, Default)]
pub struct ChaosCounters {
    /// Injected message delays (send- or recv-side) that actually slept.
    pub delays: AtomicU64,
    /// Total injected sleep time across all delay events, nanoseconds.
    pub delay_ns: AtomicU64,
    /// Frames "lost" on the wire.  The stream transport retransmits
    /// (delivery after the retransmit penalty), so a drop is a latency +
    /// accounting event, never a protocol hole — see the fault model.
    pub drops: AtomicU64,
    /// Frames delivered twice.
    pub duplicates: AtomicU64,
    /// Bit-corrupted frames that still decoded and were delivered
    /// corrupted (the receiver's semantic gates are on their own).
    pub corrupt_delivered: AtomicU64,
    /// Bit-corrupted frames the receiver's codec rejected: counted,
    /// skipped, and recovered via retransmission of the original.
    pub corrupt_rejected: AtomicU64,
    /// Messages delivered out of order (a later message overtook them
    /// inside the reorder window).
    pub reorders: AtomicU64,
    /// Worker crash events (both `Halt` and `Restart`).
    pub crashes: AtomicU64,
    /// Workers that joined the protocol late (initial join delay slept).
    pub late_joins: AtomicU64,
}

impl ChaosCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub(crate) fn add_delay(&self, ns: u64) {
        self.delays.fetch_add(1, Ordering::Relaxed);
        self.delay_ns.fetch_add(ns, Ordering::Relaxed);
    }
    pub(crate) fn add_drop(&self) {
        self.drops.fetch_add(1, Ordering::Relaxed);
    }
    pub(crate) fn add_duplicate(&self) {
        self.duplicates.fetch_add(1, Ordering::Relaxed);
    }
    pub(crate) fn add_corrupt_delivered(&self) {
        self.corrupt_delivered.fetch_add(1, Ordering::Relaxed);
    }
    pub(crate) fn add_corrupt_rejected(&self) {
        self.corrupt_rejected.fetch_add(1, Ordering::Relaxed);
    }
    pub(crate) fn add_reorder(&self) {
        self.reorders.fetch_add(1, Ordering::Relaxed);
    }
    pub(crate) fn add_crash(&self) {
        self.crashes.fetch_add(1, Ordering::Relaxed);
    }
    pub(crate) fn add_late_join(&self) {
        self.late_joins.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ChaosSnapshot {
        ChaosSnapshot {
            delays: self.delays.load(Ordering::Relaxed),
            delay_ns: self.delay_ns.load(Ordering::Relaxed),
            drops: self.drops.load(Ordering::Relaxed),
            duplicates: self.duplicates.load(Ordering::Relaxed),
            corrupt_delivered: self.corrupt_delivered.load(Ordering::Relaxed),
            corrupt_rejected: self.corrupt_rejected.load(Ordering::Relaxed),
            reorders: self.reorders.load(Ordering::Relaxed),
            crashes: self.crashes.load(Ordering::Relaxed),
            late_joins: self.late_joins.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`ChaosCounters`] — the value carried by
/// [`Report`](crate::session::Report) and sweep artifacts.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChaosSnapshot {
    pub delays: u64,
    pub delay_ns: u64,
    pub drops: u64,
    pub duplicates: u64,
    pub corrupt_delivered: u64,
    pub corrupt_rejected: u64,
    pub reorders: u64,
    pub crashes: u64,
    pub late_joins: u64,
}

impl ChaosSnapshot {
    /// Total injected events (delay time excluded — it is a magnitude,
    /// not a count).  Nonzero iff the plan actually touched the run;
    /// `scripts/check_smoke_bytes.py` asserts this on the CI smoke
    /// artifact's chaos cells.
    pub fn events_total(&self) -> u64 {
        self.delays
            + self.drops
            + self.duplicates
            + self.corrupt_delivered
            + self.corrupt_rejected
            + self.reorders
            + self.crashes
            + self.late_joins
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_totals_every_event_kind() {
        let c = ChaosCounters::new();
        c.add_delay(500);
        c.add_delay(250);
        c.add_drop();
        c.add_duplicate();
        c.add_corrupt_delivered();
        c.add_corrupt_rejected();
        c.add_reorder();
        c.add_crash();
        c.add_late_join();
        let s = c.snapshot();
        assert_eq!(s.delays, 2);
        assert_eq!(s.delay_ns, 750);
        assert_eq!(s.events_total(), 9);
        assert_eq!(ChaosSnapshot::default().events_total(), 0);
    }
}
