//! [`ChaosWorker`]: the fault-injecting [`WorkerLink`] decorator.
//!
//! The wrapper sits *above* a real transport endpoint (local channel or
//! TCP socket) and below the protocol loop, so every solver runs over it
//! unchanged and both transports see the exact same injected fates.
//! Faults are injected on the worker side of the link for both
//! directions, because a worker's sequence of link operations is
//! deterministic (its protocol loop is sequential) while the master's
//! receive order is not — injecting here is what makes a plan replay
//! bit-identically across transports.
//!
//! Delivery discipline (see the fault-model table in [`crate::chaos`]):
//! a dropped or codec-rejected frame is re-delivered after the plan's
//! retransmit penalty — the links model *stream* transports, which
//! retransmit rather than lose frames — and held (reordered) frames are
//! flushed before the worker blocks on `recv`, so a ping-pong protocol
//! can never deadlock on its own held message.

use std::sync::Arc;
use std::time::Duration;

use crate::chaos::counters::ChaosCounters;
use crate::chaos::plan::{CrashMode, FaultPlan, RankPlan};
use crate::comms::{Wire, WorkerLink};
use crate::util::rng::Rng;

/// Everything the harness needs to install chaos on a run's worker
/// links: the shared plan, the shared event counters, and the
/// protocol's corruption guard (leading payload bytes — routing and
/// barrier-identity fields — that bit flips must not touch; corrupting
/// those models Byzantine misrouting, which no solver here claims to
/// tolerate).
#[derive(Clone)]
pub struct ChaosInject {
    pub plan: Arc<FaultPlan>,
    pub counters: Arc<ChaosCounters>,
    pub guard: usize,
}

impl ChaosInject {
    pub fn new(plan: FaultPlan) -> ChaosInject {
        ChaosInject {
            plan: Arc::new(plan),
            counters: Arc::new(ChaosCounters::new()),
            guard: 0,
        }
    }

    /// Wrap rank `rank`'s endpoint in its scripted fault layer.
    pub fn wrap<Up: Wire, Down: Wire>(
        &self,
        rank: usize,
        inner: Box<dyn WorkerLink<Up, Down>>,
    ) -> Box<dyn WorkerLink<Up, Down>> {
        Box::new(ChaosWorker::new(inner, &self.plan, rank, self.counters.clone(), self.guard))
    }
}

struct Held<Up> {
    msg: Up,
    /// Later sends this message may still be deferred past.
    remaining: u32,
    /// Messages actually delivered ahead of it while held.
    passed: u32,
}

/// Fault-injecting decorator over any worker-side link endpoint.
pub struct ChaosWorker<Up, Down> {
    /// `None` after a [`CrashMode::Halt`]: the "process" is dead — sends
    /// vanish, receives report a closed link.
    inner: Option<Box<dyn WorkerLink<Up, Down>>>,
    plan: RankPlan,
    retransmit: Duration,
    rng: Rng,
    counters: Arc<ChaosCounters>,
    guard: usize,
    /// Uplink send index (drives the crash script).
    sent: u64,
    joined: bool,
    held: Vec<Held<Up>>,
}

enum CorruptFate<Up> {
    /// The flipped frame still decoded: deliver it corrupted.
    Delivered(Up),
    /// The receiver's codec rejected the flipped frame.
    Rejected,
    /// Payload no larger than the guard: nothing corruptible.
    TooSmall,
}

/// Re-materialize a message through its own codec (frame-accurate
/// duplication without a `Clone` bound on the protocol types).
fn reencode<W: Wire>(msg: &W) -> W {
    let mut payload = Vec::new();
    msg.encode(&mut payload);
    // lint: allow(panic-free): encode/decode round-tripping is exactly the
    // invariant the wire property tests pin for every Wire type; a failure
    // here is a codec bug that must abort the chaos run loudly.
    W::decode(msg.tag(), &payload).expect("re-decoding an encoded message cannot fail")
}

impl<Up: Wire, Down: Wire> ChaosWorker<Up, Down> {
    pub fn new(
        inner: Box<dyn WorkerLink<Up, Down>>,
        plan: &FaultPlan,
        rank: usize,
        counters: Arc<ChaosCounters>,
        guard: usize,
    ) -> ChaosWorker<Up, Down> {
        ChaosWorker {
            inner: Some(inner),
            plan: plan.rank(rank).clone(),
            retransmit: plan.retransmit,
            rng: plan.rank_rng(rank),
            counters,
            guard,
            sent: 0,
            joined: false,
            held: Vec::new(),
        }
    }

    fn join_once(&mut self) {
        if !self.joined {
            self.joined = true;
            if let Some(d) = self.plan.join_delay {
                if d > Duration::ZERO {
                    self.counters.add_late_join();
                    std::thread::sleep(d);
                }
            }
        }
    }

    fn sleep_counted(&mut self, d: Duration) {
        self.counters.add_delay(d.as_nanos() as u64);
        std::thread::sleep(d);
    }

    fn deliver(&mut self, msg: Up) {
        if let Some(inner) = &mut self.inner {
            inner.send(msg);
        }
    }

    fn corrupt(&mut self, msg: &Up) -> CorruptFate<Up> {
        let tag = msg.tag();
        let mut payload = Vec::new();
        msg.encode(&mut payload);
        if payload.len() <= self.guard {
            return CorruptFate::TooSmall;
        }
        let bits = (payload.len() - self.guard) * 8;
        let bit = self.guard * 8 + self.rng.next_below(bits);
        payload[bit / 8] ^= 1 << (bit % 8);
        match Up::decode(tag, &payload) {
            Ok(m) => CorruptFate::Delivered(m),
            Err(_) => CorruptFate::Rejected,
        }
    }

    /// Age previously-held messages by one send call and release the
    /// expired ones (in FIFO order, after this call's deliveries).
    fn age_held(&mut self, delivered_now: u32, skip_newest: bool) {
        let aged = self.held.len() - usize::from(skip_newest && !self.held.is_empty());
        for h in self.held.iter_mut().take(aged) {
            h.remaining = h.remaining.saturating_sub(1);
            h.passed += delivered_now;
        }
        let mut i = 0;
        while i < self.held.len() {
            if self.held[i].remaining == 0 {
                let h = self.held.remove(i);
                if h.passed > 0 {
                    self.counters.add_reorder();
                }
                self.deliver(h.msg);
            } else {
                i += 1;
            }
        }
    }

    /// Release every held message (FIFO) — called before blocking on
    /// `recv`, where holding longer could deadlock a ping-pong protocol.
    fn flush_held(&mut self) {
        let held = std::mem::take(&mut self.held);
        for h in held {
            if h.passed > 0 {
                self.counters.add_reorder();
            }
            self.deliver(h.msg);
        }
    }
}

impl<Up: Wire, Down: Wire> WorkerLink<Up, Down> for ChaosWorker<Up, Down> {
    fn send(&mut self, msg: Up) {
        self.join_once();
        // Scripted crash fires when the rank is about to make send
        // #at_send (0-based) — same instant on every transport.
        if let Some(crash) = self.plan.crash {
            if self.sent == crash.at_send {
                self.counters.add_crash();
                match crash.mode {
                    CrashMode::Halt => {
                        // the process dies: link closes, in-flight
                        // (held) frames are lost with it
                        self.inner = None;
                        self.held.clear();
                    }
                    CrashMode::Restart { stall } => std::thread::sleep(stall),
                }
            }
        }
        self.sent += 1;
        if self.inner.is_none() {
            return;
        }
        // Fault draws happen in a FIXED order per message, so each
        // rank's decision stream is a pure function of (plan seed, rank,
        // op index) — the replay guarantee.
        let plan = self.plan.clone();
        if let Some(d) = plan.send_delay.draw(&mut self.rng) {
            self.sleep_counted(d);
        }
        if plan.drop_prob > 0.0 && self.rng.next_f64() < plan.drop_prob {
            // the frame is lost; the stream transport retransmits it
            self.counters.add_drop();
            std::thread::sleep(self.retransmit);
        }
        let mut msg = msg;
        if plan.corrupt_prob > 0.0 && self.rng.next_f64() < plan.corrupt_prob {
            match self.corrupt(&msg) {
                CorruptFate::Delivered(m) => {
                    self.counters.add_corrupt_delivered();
                    msg = m;
                }
                CorruptFate::Rejected => {
                    // receiver codec discards it; original retransmitted
                    self.counters.add_corrupt_rejected();
                    std::thread::sleep(self.retransmit);
                }
                CorruptFate::TooSmall => {}
            }
        }
        let dup = plan.dup_prob > 0.0 && self.rng.next_f64() < plan.dup_prob;
        let hold = match plan.reorder {
            Some(r) if r.window > 0 && r.prob > 0.0 && self.rng.next_f64() < r.prob => {
                1 + self.rng.next_below(r.window as usize) as u32
            }
            _ => 0,
        };
        let dup_copy = if dup { Some(reencode(&msg)) } else { None };
        let mut delivered_now = 0u32;
        if hold > 0 {
            self.held.push(Held { msg, remaining: hold, passed: 0 });
        } else {
            self.deliver(msg);
            delivered_now += 1;
        }
        if let Some(copy) = dup_copy {
            self.counters.add_duplicate();
            self.deliver(copy);
            delivered_now += 1;
        }
        self.age_held(delivered_now, hold > 0);
    }

    fn recv(&mut self) -> Option<Down> {
        self.join_once();
        self.flush_held();
        let msg = self.inner.as_mut()?.recv()?;
        if let Some(d) = self.plan.recv_delay.draw(&mut self.rng) {
            self.sleep_counted(d);
        }
        Some(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::plan::{Crash, DelayModel, Reorder};
    use crate::comms::local::local_links;
    use crate::coordinator::messages::{MasterMsg, UpdateMsg};
    use crate::metrics::Counters;

    fn upd(rank: u32, t_w: u64) -> UpdateMsg {
        UpdateMsg::dense(rank, t_w, vec![0.25; 6], vec![-0.5; 6], 1.0, 0.5, 8, 0.0)
    }

    /// A chaos-wrapped rank-0 worker over in-process links, plus the
    /// master endpoint and the chaos counters.
    fn rig(
        plan: FaultPlan,
    ) -> (
        crate::comms::LocalMaster<UpdateMsg, MasterMsg>,
        ChaosWorker<UpdateMsg, MasterMsg>,
        Arc<ChaosCounters>,
    ) {
        let counters = Arc::new(Counters::new());
        let (master, mut workers) = local_links::<UpdateMsg, MasterMsg>(1, counters, None);
        let inject = ChaosInject { guard: 4, ..ChaosInject::new(plan) };
        let chaos = inject.counters.clone();
        let inner: Box<dyn WorkerLink<UpdateMsg, MasterMsg>> = Box::new(workers.remove(0));
        let wrapped = ChaosWorker::new(inner, &inject.plan, 0, chaos.clone(), inject.guard);
        (master, wrapped, chaos)
    }

    #[test]
    fn clean_plan_is_a_transparent_passthrough() {
        let (mut master, mut w, chaos) = rig(FaultPlan::clean(1));
        for t in 0..5 {
            w.send(upd(0, t));
        }
        for t in 0..5 {
            assert_eq!(master.recv().unwrap().t_w, t);
        }
        master.send_to(0, MasterMsg::Stop);
        assert!(matches!(w.recv(), Some(MasterMsg::Stop)));
        assert_eq!(chaos.snapshot().events_total(), 0);
    }

    #[test]
    fn dropped_frames_are_retransmitted_not_lost() {
        let mut plan = FaultPlan::clean(2);
        plan.default_rank.drop_prob = 1.0;
        plan.retransmit = Duration::from_micros(50);
        let (mut master, mut w, chaos) = rig(plan);
        for t in 0..8 {
            w.send(upd(0, t));
        }
        for t in 0..8 {
            assert_eq!(master.recv().unwrap().t_w, t, "dropped frame truly lost");
        }
        assert_eq!(chaos.snapshot().drops, 8);
    }

    #[test]
    fn duplicates_arrive_twice() {
        let mut plan = FaultPlan::clean(3);
        plan.default_rank.dup_prob = 1.0;
        let (mut master, mut w, chaos) = rig(plan);
        for t in 0..4 {
            w.send(upd(0, t));
        }
        let mut seen = Vec::new();
        for _ in 0..8 {
            seen.push(master.recv().unwrap().t_w);
        }
        assert_eq!(seen, vec![0, 0, 1, 1, 2, 2, 3, 3]);
        assert_eq!(chaos.snapshot().duplicates, 4);
    }

    #[test]
    fn corruption_respects_the_guard_and_never_loses_messages() {
        let mut plan = FaultPlan::clean(4);
        plan.default_rank.corrupt_prob = 1.0;
        plan.retransmit = Duration::from_micros(10);
        let (mut master, mut w, chaos) = rig(plan);
        let n = 128u64;
        for t in 0..n {
            w.send(upd(0, t));
        }
        for _ in 0..n {
            let got = master.recv().unwrap();
            // guard = 4 protects worker_id: routing identity survives
            assert_eq!(got.worker_id, 0);
        }
        let s = chaos.snapshot();
        assert_eq!(s.corrupt_delivered + s.corrupt_rejected, n);
        assert!(s.corrupt_delivered > 0, "some flips must decode");
        assert!(s.corrupt_rejected > 0, "some flips must be rejected by the codec");
    }

    #[test]
    fn reordering_actually_inverts_and_preserves_the_message_set() {
        let mut plan = FaultPlan::clean(5);
        plan.default_rank.reorder = Some(Reorder { window: 2, prob: 0.5 });
        let (mut master, mut w, chaos) = rig(plan);
        let n = 40u64;
        for t in 0..n {
            w.send(upd(0, t));
        }
        // flush any trailing held frame the way a protocol would: by
        // blocking on recv
        master.send_to(0, MasterMsg::Stop);
        assert!(matches!(w.recv(), Some(MasterMsg::Stop)));
        let mut seen = Vec::new();
        for _ in 0..n {
            seen.push(master.recv().unwrap().t_w);
        }
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n).collect::<Vec<_>>(), "messages lost or duplicated");
        assert_ne!(seen, sorted, "no inversion ever happened");
        assert!(chaos.snapshot().reorders > 0);
    }

    #[test]
    fn halt_crash_kills_the_link_at_the_scripted_send() {
        let mut plan = FaultPlan::clean(6);
        plan.default_rank.crash = Some(Crash { at_send: 3, mode: CrashMode::Halt });
        let (mut master, mut w, chaos) = rig(plan);
        for t in 0..6 {
            w.send(upd(0, t));
        }
        for t in 0..3 {
            assert_eq!(master.recv().unwrap().t_w, t);
        }
        assert!(w.recv().is_none(), "a halted worker's link must read as closed");
        assert_eq!(chaos.snapshot().crashes, 1);
        // master now sees the disconnect (wrapper dropped its sender)
        drop(w);
        assert!(master.recv().is_none());
    }

    #[test]
    fn same_plan_same_rank_replays_identically() {
        let run = || {
            let mut plan = FaultPlan::flaky_net(7);
            plan.retransmit = Duration::from_micros(10);
            plan.default_rank.send_delay = DelayModel::None;
            plan.default_rank.recv_delay = DelayModel::None;
            let (mut master, mut w, chaos) = rig(plan);
            for t in 0..50 {
                w.send(upd(0, t));
            }
            master.send_to(0, MasterMsg::Stop);
            assert!(matches!(w.recv(), Some(MasterMsg::Stop)));
            // drain exactly what was delivered: 50 + duplicates
            let mut seen = Vec::new();
            let expect = 50 + chaos.snapshot().duplicates;
            for _ in 0..expect {
                seen.push(master.recv().unwrap().t_w);
            }
            (seen, chaos.snapshot())
        };
        let (seq_a, snap_a) = run();
        let (seq_b, snap_b) = run();
        assert_eq!(seq_a, seq_b, "delivery order must replay bit-identically");
        assert_eq!(snap_a, snap_b, "event accounting must replay bit-identically");
        assert!(snap_a.events_total() > 0);
    }

    #[test]
    fn restart_crash_delays_but_continues() {
        let mut plan = FaultPlan::clean(8);
        plan.default_rank.crash = Some(Crash {
            at_send: 2,
            mode: CrashMode::Restart { stall: Duration::from_millis(1) },
        });
        let (mut master, mut w, chaos) = rig(plan);
        for t in 0..5 {
            w.send(upd(0, t));
        }
        for t in 0..5 {
            assert_eq!(master.recv().unwrap().t_w, t);
        }
        assert_eq!(chaos.snapshot().crashes, 1);
    }

    #[test]
    fn late_join_sleeps_once_before_the_first_op() {
        let mut plan = FaultPlan::clean(9);
        plan.default_rank.join_delay = Some(Duration::from_millis(1));
        let (mut master, mut w, chaos) = rig(plan);
        w.send(upd(0, 0));
        w.send(upd(0, 1));
        assert_eq!(master.recv().unwrap().t_w, 0);
        assert_eq!(chaos.snapshot().late_joins, 1, "join delay fires exactly once");
    }
}
