//! [`FaultPlan`]: the declarative, seeded script of faults a run is
//! subjected to.  A plan is data — it carries no clocks and no
//! randomness of its own; every decision made from it is drawn from a
//! per-rank RNG derived from `(plan.seed, rank)`, so the same plan
//! replays the same fates regardless of transport or wall-clock timing.
//!
//! Plans are built programmatically (tests) or from the named presets
//! the `[chaos]` config section / `--chaos.plan` CLI key and the sweep
//! `chaos` axis accept: [`FaultPlan::PRESETS`].

use std::time::Duration;

use crate::chaos::ChaosError;
use crate::util::rng::Rng;

/// Default seed for preset plans resolved from config (`--chaos.seed`
/// overrides).  Fixed so that two invocations of the same preset replay
/// the same fault script by default.
pub const DEFAULT_CHAOS_SEED: u64 = 0xC4A05;

/// Per-message latency model.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum DelayModel {
    /// No injected delay (no RNG consumed).
    #[default]
    None,
    /// Every message sleeps exactly this long.
    Fixed(Duration),
    /// Heavy-tailed delay in the style of the paper's Assumption 3: the
    /// message sleeps `unit * (Geometric(p) - 1)` — usually nothing,
    /// occasionally a long tail (small `p` = heavier tail).
    Geometric { unit: Duration, p: f64 },
}

impl DelayModel {
    /// Draw one delay.  Consumes RNG only for the geometric model, so a
    /// rank's decision stream is a function of its enabled faults.
    pub(crate) fn draw(&self, rng: &mut Rng) -> Option<Duration> {
        match *self {
            DelayModel::None => None,
            DelayModel::Fixed(d) => (d > Duration::ZERO).then_some(d),
            DelayModel::Geometric { unit, p } => {
                let mult = rng.geometric(p).saturating_sub(1);
                (mult > 0).then(|| unit.saturating_mul(mult.min(u32::MAX as u64) as u32))
            }
        }
    }
}

/// Reorder-within-window: a sent message may be held and released only
/// after up to `window` later sends (or when the worker next blocks on
/// `recv`, whichever comes first — holding past that point would
/// deadlock a ping-pong protocol).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Reorder {
    pub window: u32,
    pub prob: f64,
}

/// What happens when a worker crashes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CrashMode {
    /// The worker process dies: its link closes, nothing it held is
    /// delivered, and it never comes back.  Only solvers that tolerate
    /// worker loss (the asynchronous ones) accept plans containing this.
    Halt,
    /// Crash-and-recover: the worker freezes for `stall`, then resumes.
    /// Composed with the async protocols this exercises the paper's
    /// actual recovery path — the stalled worker's next update is stale,
    /// gets dropped by the delay gate, and the master resynchronizes it
    /// with a catch-up slice.
    Restart { stall: Duration },
}

/// Scripted crash: fires when the rank is about to make its
/// `at_send`-th uplink send (0-based).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Crash {
    pub at_send: u64,
    pub mode: CrashMode,
}

/// The fault script of one worker rank.  `Default` is fully inert.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RankPlan {
    /// Injected latency per uplink (worker -> master) message.
    pub send_delay: DelayModel,
    /// Injected latency per downlink (master -> worker) message.
    pub recv_delay: DelayModel,
    /// Probability an uplink frame is lost on the wire (recovered by
    /// retransmission after [`FaultPlan::retransmit`]).
    pub drop_prob: f64,
    /// Probability an uplink frame is delivered twice.
    pub dup_prob: f64,
    /// Probability an uplink frame has one payload bit flipped.
    pub corrupt_prob: f64,
    /// Reorder-within-window on the uplink.
    pub reorder: Option<Reorder>,
    /// Scripted crash at a fixed send index.
    pub crash: Option<Crash>,
    /// Late join: sleep this long before the rank's first protocol op.
    pub join_delay: Option<Duration>,
}

impl RankPlan {
    /// True when this rank's script injects nothing.
    pub fn is_inert(&self) -> bool {
        *self == RankPlan::default()
    }
}

/// A complete, seeded fault-injection script for one run.
///
/// `default_rank` applies to every rank without an entry in
/// `overrides`.  See the module docs of [`crate::chaos`] for the fault
/// model and the determinism guarantees.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Label used in spec echoes and as the sweep `chaos` axis value
    /// (a preset name, or `"custom"` for programmatic plans).
    pub name: String,
    /// Seed of the per-rank decision RNGs.
    pub seed: u64,
    /// Script applied to ranks without an override.
    pub default_rank: RankPlan,
    /// `(rank, script)` overrides.
    pub overrides: Vec<(usize, RankPlan)>,
    /// Retransmission penalty paid when a frame is dropped or rejected
    /// as corrupt: the original is delivered after this much extra
    /// latency (stream transports retransmit; they do not lose frames).
    pub retransmit: Duration,
}

impl FaultPlan {
    /// Names accepted by [`FaultPlan::preset`], the `[chaos]` config
    /// section and the sweep `chaos` axis (which additionally accepts
    /// `"none"` = no injection at all).
    pub const PRESETS: &'static [&'static str] =
        &["clean", "slow-tail", "flaky-net", "crash-1"];

    /// An inert plan named `name` (building block for the presets).
    fn named(name: &str, seed: u64) -> FaultPlan {
        FaultPlan {
            name: name.to_string(),
            seed,
            default_rank: RankPlan::default(),
            overrides: Vec::new(),
            retransmit: Duration::from_millis(1),
        }
    }

    /// Fully inert plan: the wrapper is installed (so the event counters
    /// exist and read zero) but injects nothing.  The control cell of
    /// every chaos comparison.
    pub fn clean(seed: u64) -> FaultPlan {
        FaultPlan::named("clean", seed)
    }

    /// One heavy-tailed straggler rank (rank 0), everyone else clean —
    /// the paper's Assumption-3 scenario on the wire instead of in the
    /// compute model.
    pub fn slow_tail(seed: u64) -> FaultPlan {
        let mut p = FaultPlan::named("slow-tail", seed);
        p.overrides.push((
            0,
            RankPlan {
                send_delay: DelayModel::Geometric { unit: Duration::from_micros(300), p: 0.25 },
                ..RankPlan::default()
            },
        ));
        p
    }

    /// Every rank sees a lossy, jittery, occasionally-corrupting link:
    /// fixed per-message latency (guarantees nonzero delay events — the
    /// CI smoke check relies on that), drops, duplicates, bit flips and
    /// a small reorder window.
    pub fn flaky_net(seed: u64) -> FaultPlan {
        let mut p = FaultPlan::named("flaky-net", seed);
        p.default_rank = RankPlan {
            send_delay: DelayModel::Fixed(Duration::from_micros(200)),
            recv_delay: DelayModel::Geometric { unit: Duration::from_micros(100), p: 0.5 },
            drop_prob: 0.10,
            dup_prob: 0.08,
            corrupt_prob: 0.06,
            reorder: Some(Reorder { window: 2, prob: 0.10 }),
            crash: None,
            join_delay: None,
        };
        p
    }

    /// Rank 0 crashes at its 5th send and recovers after a stall; rank 1
    /// (when present) joins late.  `Restart` rather than `Halt` so the
    /// synchronous barrier solver survives the same preset the async
    /// solvers do — true worker death is Halt, which sfw-dist rejects
    /// at spec validation (its barrier cannot outlive a worker).
    pub fn crash_one(seed: u64) -> FaultPlan {
        let mut p = FaultPlan::named("crash-1", seed);
        p.overrides.push((
            0,
            RankPlan {
                crash: Some(Crash {
                    at_send: 5,
                    mode: CrashMode::Restart { stall: Duration::from_millis(30) },
                }),
                ..RankPlan::default()
            },
        ));
        p.overrides.push((
            1,
            RankPlan {
                join_delay: Some(Duration::from_millis(10)),
                ..RankPlan::default()
            },
        ));
        p
    }

    /// Resolve a preset by name ([`FaultPlan::PRESETS`]); unknown names
    /// error with the valid listing, registry-style.
    pub fn preset(name: &str, seed: u64) -> Result<FaultPlan, ChaosError> {
        match name {
            "clean" => Ok(FaultPlan::clean(seed)),
            "slow-tail" => Ok(FaultPlan::slow_tail(seed)),
            "flaky-net" => Ok(FaultPlan::flaky_net(seed)),
            "crash-1" => Ok(FaultPlan::crash_one(seed)),
            other => Err(ChaosError::UnknownPlan {
                value: other.to_string(),
                valid: FaultPlan::PRESETS.join(" | "),
            }),
        }
    }

    /// The script applied to `rank`.
    pub fn rank(&self, rank: usize) -> &RankPlan {
        self.overrides
            .iter()
            .find(|(r, _)| *r == rank)
            .map(|(_, p)| p)
            .unwrap_or(&self.default_rank)
    }

    /// Decision RNG of `rank`: a pure function of `(seed, rank)` — the
    /// root of the bit-identical-replay guarantee.
    pub fn rank_rng(&self, rank: usize) -> Rng {
        Rng::new(self.seed ^ (rank as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// True if any rank's script can permanently kill a worker
    /// ([`CrashMode::Halt`]).  Solvers whose protocol cannot outlive a
    /// worker (the synchronous barrier) reject such plans up front.
    pub fn has_halt(&self) -> bool {
        let halts = |p: &RankPlan| {
            matches!(p.crash, Some(Crash { mode: CrashMode::Halt, .. }))
        };
        halts(&self.default_rank) || self.overrides.iter().any(|(_, p)| halts(p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_unknown_lists_valid_names() {
        for name in FaultPlan::PRESETS {
            let p = FaultPlan::preset(name, 7).unwrap();
            assert_eq!(&p.name, name);
            assert_eq!(p.seed, 7);
        }
        let err = FaultPlan::preset("slow-taill", 7).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("slow-taill"), "{msg}");
        for name in FaultPlan::PRESETS {
            assert!(msg.contains(name), "error should list '{name}': {msg}");
        }
    }

    #[test]
    fn rank_overrides_fall_back_to_default() {
        let p = FaultPlan::slow_tail(1);
        assert!(!p.rank(0).is_inert());
        assert!(p.rank(1).is_inert());
        assert!(p.rank(17).is_inert());
        assert!(FaultPlan::flaky_net(1).rank(17).drop_prob > 0.0);
    }

    #[test]
    fn rank_rngs_are_deterministic_and_distinct() {
        let p = FaultPlan::flaky_net(42);
        let mut a = p.rank_rng(0);
        let mut b = p.rank_rng(0);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut a = p.rank_rng(0);
        let mut c = p.rank_rng(1);
        let same = (0..64).filter(|_| a.next_u64() == c.next_u64()).count();
        assert!(same < 2, "rank streams must differ");
    }

    #[test]
    fn halt_detection() {
        assert!(!FaultPlan::crash_one(1).has_halt(), "crash-1 is a Restart preset");
        let mut p = FaultPlan::clean(1);
        p.overrides.push((
            0,
            RankPlan {
                crash: Some(Crash { at_send: 2, mode: CrashMode::Halt }),
                ..RankPlan::default()
            },
        ));
        assert!(p.has_halt());
    }

    #[test]
    fn delay_models_draw_deterministically() {
        let mut rng = Rng::new(5);
        assert_eq!(DelayModel::None.draw(&mut rng), None);
        assert_eq!(
            DelayModel::Fixed(Duration::from_micros(10)).draw(&mut rng),
            Some(Duration::from_micros(10))
        );
        assert_eq!(DelayModel::Fixed(Duration::ZERO).draw(&mut rng), None);
        let g = DelayModel::Geometric { unit: Duration::from_micros(10), p: 0.5 };
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        for _ in 0..100 {
            assert_eq!(g.draw(&mut r1), g.draw(&mut r2));
        }
    }
}
