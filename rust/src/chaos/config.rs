//! `[chaos]` configuration: resolve an optional [`FaultPlan`] from the
//! same INI-subset config file + CLI overrides the launcher uses.
//!
//! Keys are accepted only in their sectioned spelling (`plan = flaky-net`
//! under `[chaos]` in the file, `--chaos.plan flaky-net` on the CLI) —
//! chaos is an orthogonal concern, not a `[train]` knob.  An unknown
//! `chaos.*` key errors with the valid-key listing, the same contract as
//! the `[sweep]` section and the solver registry; and subcommands that
//! cannot inject faults (`sfw worker`, `sfw simulate`, `sfw info`)
//! reject `[chaos]`/`--chaos.*` outright instead of silently ignoring a
//! plan the user thinks is active.

use crate::chaos::plan::{FaultPlan, DEFAULT_CHAOS_SEED};
use crate::chaos::ChaosError;
use crate::config::Config;
use crate::util::cli::Args;

/// Keys the `[chaos]` section accepts.
pub const CHAOS_KEYS: &[&str] = &["plan", "seed"];

/// Reject unknown / valueless `chaos.*` keys in both sources.
fn check_keys(file: &Config, args: &Args) -> Result<(), ChaosError> {
    for key in file.keys().map(String::as_str).chain(args.flag_keys().map(String::as_str)) {
        if let Some(suffix) = key.strip_prefix("chaos.") {
            if !CHAOS_KEYS.contains(&suffix) {
                return Err(ChaosError::UnknownKey {
                    key: suffix.to_string(),
                    valid: CHAOS_KEYS.join(" | "),
                });
            }
            if args.has(key) && args.get_opt(key).is_none() {
                return Err(ChaosError::BadValue {
                    key: suffix.to_string(),
                    value: String::new(),
                    expected: format!("a value (--chaos.{suffix} <value>)"),
                });
            }
        }
    }
    Ok(())
}

/// Resolve the `[chaos]` section + `--chaos.*` CLI overrides into an
/// optional plan (CLI beats file, like every other section).  `None`
/// when neither source configures a plan.
pub fn resolve(file: &Config, args: &Args) -> Result<Option<FaultPlan>, ChaosError> {
    check_keys(file, args)?;
    let get = |key: &str| -> Option<String> {
        args.get_opt(&format!("chaos.{key}"))
            .or_else(|| file.get_opt(&format!("chaos.{key}")))
    };
    let seed = match get("seed") {
        None => DEFAULT_CHAOS_SEED,
        Some(v) => v.parse().map_err(|_| ChaosError::BadValue {
            key: "seed".into(),
            value: v,
            expected: "an unsigned integer".into(),
        })?,
    };
    match get("plan") {
        None => {
            // a bare seed with no plan is a misconfiguration, not a no-op
            if get("seed").is_some() {
                return Err(ChaosError::BadValue {
                    key: "seed".into(),
                    value: seed.to_string(),
                    expected: "a `plan` key alongside it (seed alone injects nothing)".into(),
                });
            }
            Ok(None)
        }
        Some(name) if name.eq_ignore_ascii_case("none") => Ok(None),
        Some(name) => Ok(Some(FaultPlan::preset(&name, seed)?)),
    }
}

/// Reject any chaos configuration on a subcommand that cannot honor it.
pub fn reject_chaos_keys(cmd: &str, file: &Config, args: &Args) -> Result<(), ChaosError> {
    let offending = file
        .keys()
        .map(String::as_str)
        .chain(args.flag_keys().map(String::as_str))
        .find(|k| k.starts_with("chaos."));
    match offending {
        Some(key) => Err(ChaosError::NotApplicable {
            cmd: cmd.to_string(),
            key: key.to_string(),
        }),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn no_chaos_config_resolves_to_none() {
        assert!(resolve(&Config::new(), &args("")).unwrap().is_none());
        assert!(resolve(&Config::new(), &args("--chaos.plan none")).unwrap().is_none());
    }

    #[test]
    fn cli_plan_resolves_and_beats_the_file() {
        let file = Config::from_str("[chaos]\nplan = slow-tail\nseed = 9\n").unwrap();
        let p = resolve(&file, &args("")).unwrap().unwrap();
        assert_eq!(p.name, "slow-tail");
        assert_eq!(p.seed, 9);
        let p = resolve(&file, &args("--chaos.plan flaky-net")).unwrap().unwrap();
        assert_eq!(p.name, "flaky-net");
        assert_eq!(p.seed, 9, "file seed still applies under a CLI plan");
        let p = resolve(&Config::new(), &args("--chaos.plan crash-1")).unwrap().unwrap();
        assert_eq!(p.seed, DEFAULT_CHAOS_SEED);
    }

    #[test]
    fn unknown_chaos_key_lists_valid_names() {
        for source in [
            resolve(&Config::from_str("[chaos]\nplann = clean\n").unwrap(), &args("")),
            resolve(&Config::new(), &args("--chaos.plann clean")),
        ] {
            let msg = source.unwrap_err().to_string();
            assert!(msg.contains("plann"), "{msg}");
            for key in CHAOS_KEYS {
                assert!(msg.contains(key), "error should list '{key}': {msg}");
            }
        }
    }

    #[test]
    fn malformed_plan_and_values_error() {
        let err = resolve(&Config::new(), &args("--chaos.plan flakey-net")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("flakey-net") && msg.contains("flaky-net"), "{msg}");
        // valueless flag must not be coerced
        assert!(resolve(&Config::new(), &args("--chaos.plan")).is_err());
        // non-numeric seed
        assert!(resolve(&Config::new(), &args("--chaos.plan clean --chaos.seed abc")).is_err());
        // seed with no plan is a misconfiguration, not silence
        assert!(resolve(&Config::new(), &args("--chaos.seed 7")).is_err());
    }

    #[test]
    fn non_chaos_subcommands_reject_chaos_keys() {
        assert!(reject_chaos_keys("worker", &Config::new(), &args("")).is_ok());
        let err =
            reject_chaos_keys("worker", &Config::new(), &args("--chaos.plan clean")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("worker") && msg.contains("chaos.plan"), "{msg}");
        let file = Config::from_str("[chaos]\nplan = clean\n").unwrap();
        assert!(reject_chaos_keys("simulate", &file, &args("")).is_err());
    }
}
