//! `sfw::chaos` — deterministic fault injection for the comms layer.
//!
//! The paper's central claim is *robustness to asynchrony*: SFW-asyn
//! keeps the vanilla SFW rate despite stragglers and bounded delay tau
//! (Thm 1).  This module turns that claim — and every future robustness
//! claim — into a runnable scenario: a seeded [`FaultPlan`] scripts
//! delays, drops, duplicates, reorderings, bit corruption, crashes and
//! late joins per worker rank, and a [`ChaosWorker`] decorator injects
//! them behind the ordinary [`WorkerLink`](crate::comms::WorkerLink)
//! trait, so every solver and both transports run over it unchanged.
//!
//! # Fault model
//!
//! | event            | scripted by                    | semantics                                                                 | counter             |
//! |------------------|--------------------------------|---------------------------------------------------------------------------|---------------------|
//! | message delay    | `RankPlan::send_delay`/`recv_delay` (fixed or geometric) | sleep before delivery / after receipt                   | `delays`/`delay_ns` |
//! | drop             | `RankPlan::drop_prob`          | frame lost on the wire; the *stream* transport retransmits: delivered after `FaultPlan::retransmit` | `drops` |
//! | duplicate        | `RankPlan::dup_prob`           | frame delivered twice (codec-exact copy)                                  | `duplicates`        |
//! | bit corruption   | `RankPlan::corrupt_prob`       | one payload bit flipped past the protocol's corrupt guard; still-decodable frames are delivered corrupted, codec-rejected frames are counted and the original retransmitted | `corrupt_delivered`/`corrupt_rejected` |
//! | reorder          | `RankPlan::reorder` (window, prob) | frame held past up to `window` later sends; always flushed before the worker blocks on `recv` (ping-pong protocols cannot deadlock on their own held frame).  NOTE: today's three protocols are strict ping-pong — never two uplink frames in flight — so end-to-end this degrades to pass-through (`reorders` stays 0 in solver runs); the mechanism exists for pipelined protocols and is exercised by the unit tests in [`link`] | `reorders` |
//! | crash at step k  | `RankPlan::crash`              | `Halt`: link closes forever, held frames lost (async solvers only — the sfw-dist barrier rejects halting plans at spec validation); `Restart`: stall, then continue | `crashes` |
//! | late join        | `RankPlan::join_delay`         | sleep once before the rank's first protocol op                            | `late_joins`        |
//!
//! # Determinism and replay
//!
//! Every fault decision is drawn from a per-rank RNG that is a pure
//! function of `(plan.seed, rank)`, in a fixed order per link operation
//! — never from wall-clock time or arrival order.  Consequences:
//!
//! * the *fate of rank w's k-th message is identical* under
//!   `Transport::Local` and `Transport::Tcp`, and across repeated runs;
//! * for protocols whose message schedule is itself deterministic
//!   (sfw-dist's barrier rounds), whole runs replay bit-identically:
//!   same iterate, same byte totals, same event counters — pinned by
//!   `rust/tests/chaos.rs`;
//! * for the asynchronous protocols the *per-message* fates replay, but
//!   how many messages a worker sends before `Stop` depends on thread
//!   scheduling, so end-to-end event totals may differ run to run (just
//!   as `msgs_up` already does without chaos).
//!
//! Corruption never touches a protocol's first `guard` payload bytes
//! (routing and barrier-identity fields — `UpdateMsg::CORRUPT_GUARD`,
//! `DistUp::CORRUPT_GUARD`): flipping those models Byzantine
//! misrouting, which no solver here claims to tolerate.  Everything
//! after the guard — sync points, telemetry, the update vectors and
//! gradients themselves — is fair game, and the masters' semantic gates
//! (bad-rank skip, future-`t_w` rejection with a liveness-preserving
//! empty reply, gap-tolerant catch-up replay that refuses the echo of a
//! corrupted sync-point claim, unit-norm sanity check,
//! non-finite-gradient rejection) are what the conformance suite
//! exercises end to end.
//!
//! # Wiring
//!
//! `TrainSpec::fault_plan` (builder), the `[chaos]` config section /
//! `--chaos.plan`/`--chaos.seed` CLI keys ([`config`]), and the sweep
//! `chaos` axis (preset names: [`FaultPlan::PRESETS`], or `none`) all
//! install the same wrapper via `session::harness`.  Event counts
//! surface on every [`Report`](crate::session::Report) (`report.chaos`)
//! and in the sweep table/CSV/JSON artifacts; the CI smoke sweep runs a
//! `flaky-net` cell per TCP-capable solver and asserts nonzero injected
//! events (`scripts/check_smoke_bytes.py`).
//!
//! ```no_run
//! use sfw::chaos::FaultPlan;
//! use sfw::session::{TaskSpec, TrainSpec};
//!
//! let report = TrainSpec::new(TaskSpec::ms_small())
//!     .algo("sfw-asyn")
//!     .workers(4)
//!     .fault_plan(FaultPlan::flaky_net(7))
//!     .run()
//!     .expect("train under chaos");
//! println!("injected events: {}", report.chaos.events_total());
//! ```
//!
//! The chaos suite is the *dynamic* half of the robustness story; the
//! *static* half is `sfw lint` ([`crate::lint`]), which machine-checks
//! that this module and the protocol layer stay panic-free outside
//! tests and keep their wire types covered by the round-trip property
//! tests.

pub mod config;
pub mod counters;
pub mod link;
pub mod plan;

pub use config::{reject_chaos_keys, CHAOS_KEYS};
pub use counters::{ChaosCounters, ChaosSnapshot};
pub use link::{ChaosInject, ChaosWorker};
pub use plan::{
    Crash, CrashMode, DelayModel, FaultPlan, RankPlan, Reorder, DEFAULT_CHAOS_SEED,
};

/// Errors surfaced by chaos plan resolution and validation (never by the
/// injection hot path — a resolved plan cannot fail).
#[derive(Debug, thiserror::Error)]
pub enum ChaosError {
    #[error("unknown [chaos] key '{key}' (valid: {valid})")]
    UnknownKey { key: String, valid: String },
    #[error("unknown chaos plan '{value}' (valid: {valid})")]
    UnknownPlan { value: String, valid: String },
    #[error("[chaos] {key} = '{value}': expected {expected}")]
    BadValue { key: String, value: String, expected: String },
    #[error(
        "--{key} does not apply to 'sfw {cmd}': fault injection is configured on the \
         training master (use `sfw train` or `sfw sweep`)"
    )]
    NotApplicable { cmd: String, key: String },
}
