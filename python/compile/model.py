"""L2: the paper's compute graphs in JAX, calling the L1 Pallas kernels.

These functions are what `aot.py` lowers (once, at build time) to HLO text
for the Rust runtime — Python never runs on the request path.  Five module
families are exported per objective:

  *_step  : fused minibatch SUM-gradient -> power-iteration LMO, the per-
            worker hot path of Algorithm 3 (one PJRT call per worker step).
            Returns (u, v, sigma, loss_sum): the rank-one LMO direction is
            -theta * u v^T, sigma = u^T G v >= 0, and loss_sum rides along
            for free (same pass over the batch).
  *_grad  : SUM-gradient + SUM-loss only — the building block the Rust side
            composes for SVRF(-asyn)'s variance-reduced gradients
            (grad(X) - grad(W) on the batch, plus the cached full grad(W)).
  *_loss  : SUM-loss only, for cheap full-objective evaluation in chunks.
  lmo     : standalone power-iteration LMO on an explicit gradient matrix
            (used by SVRF where the VR gradient is assembled in Rust).

All graphs take float32, fixed (bucketed) shapes; gradients/losses are
SUMS over the batch — the Rust caller divides by the true, un-padded m so
zero-padded rows are exact (see kernels/ref.py).

CPU-interpret note: the kernels are tiled for TPU VMEM (DESIGN.md
§Hardware-Adaptation), but interpret-mode Pallas executes its grid loop
through dynamic-slice machinery that the CPU XLA pipeline cannot fuse —
a 4-step grid costs ~20-400x a single-block call (see EXPERIMENTS.md
§Perf).  The AOT graphs therefore lower every kernel with ONE full-size
block (`tile = full dim`); the multi-tile schedule remains exercised by
the pytest/hypothesis suites and is what a real-TPU build would use.
"""

import jax
import jax.numpy as jnp

from .kernels import ms_grad, mtv, mv, pnn_grad

_EPS = 1e-12


def lmo_power(g, v0, iters: int):
    """Leading singular pair of g by alternating power iteration.

    Args:
      g: (D1, D2) gradient matrix; v0: (D2,) start vector (the Rust caller
        randomizes it per call to avoid adversarial orthogonal starts).
      iters: fixed iteration count (static; baked into the artifact).
    Returns:
      (u (D1,), v (D2,), sigma ()) with u = g v / ||g v||, sigma >= 0.
    """
    d1, d2 = g.shape
    v = v0 / (jnp.linalg.norm(v0) + _EPS)

    def body(_, carry):
        _, v = carry
        u = mv(g, v, tile_r=d1)
        u = u / (jnp.linalg.norm(u) + _EPS)
        v = mtv(g, u, tile_c=d2)
        v = v / (jnp.linalg.norm(v) + _EPS)
        return (u, v)

    u0 = mv(g, v, tile_r=d1)
    u0 = u0 / (jnp.linalg.norm(u0) + _EPS)
    u, v = jax.lax.fori_loop(0, iters, body, (u0, v))
    sigma = u @ mv(g, v, tile_r=d1)
    return u, v, sigma


# ---------------------------------------------------------------- matrix sensing


def ms_step(af, y, xf, v0, *, d1: int, d2: int, power_iters: int):
    """Worker hot path: minibatch gradient -> LMO, fused in one module."""
    grad_flat, loss_sum = ms_grad(af, y, xf, tile_m=af.shape[0])
    g = grad_flat.reshape(d1, d2)
    u, v, sigma = lmo_power(g, v0, power_iters)
    return u, v, sigma, loss_sum


def ms_grad_module(af, y, xf):
    """SUM-gradient (flattened) + SUM-loss (SVRF building block)."""
    return ms_grad(af, y, xf, tile_m=af.shape[0])


def ms_loss_module(af, y, xf):
    """SUM-loss only (evaluation path; reuses the fused kernel)."""
    _, loss_sum = ms_grad(af, y, xf, tile_m=af.shape[0])
    return (loss_sum,)


# ---------------------------------------------------------------------- PNN


def pnn_step(a, y, x, v0, *, power_iters: int):
    """Worker hot path for the PNN objective."""
    g, loss_sum = pnn_grad(a, y, x, tile_m=a.shape[0])
    u, v, sigma = lmo_power(g, v0, power_iters)
    return u, v, sigma, loss_sum


def pnn_grad_module(a, y, x):
    return pnn_grad(a, y, x, tile_m=a.shape[0])


def pnn_loss_module(a, y, x):
    _, loss_sum = pnn_grad(a, y, x, tile_m=a.shape[0])
    return (loss_sum,)


# ------------------------------------------------------- device-resident gather


def ms_step_idx(af_full, y_full, idx, xf, v0, *, d1: int, d2: int, power_iters: int):
    """Gather-based worker step: the FULL (padded) dataset stays device-
    resident across calls; per call only the sampled indices (i32), the
    flattened iterate and the LMO start vector cross the host boundary.
    This removed the dominant per-step cost of the PJRT hot path (a
    multi-MB batch upload per call — EXPERIMENTS.md §Perf).

    `af_full` has N_max + 1 rows; row N_max is all-zero with y = 0, and
    padding slots of `idx` point at it (exact no-op under SUM semantics).
    """
    af = jnp.take(af_full, idx, axis=0)
    y = jnp.take(y_full, idx, axis=0)
    return ms_step(af, y, xf, v0, d1=d1, d2=d2, power_iters=power_iters)


def pnn_step_idx(a_full, y_full, idx, x, v0, *, power_iters: int):
    """Gather-based PNN worker step (see ms_step_idx)."""
    a = jnp.take(a_full, idx, axis=0)
    y = jnp.take(y_full, idx, axis=0)
    return pnn_step(a, y, x, v0, power_iters=power_iters)


# --------------------------------------------------------------- standalone LMO


def lmo_module(g, v0, *, power_iters: int):
    """Standalone LMO on an explicit (D1, D2) gradient matrix."""
    u, v, sigma = lmo_power(g, v0, power_iters)
    return u, v, sigma
