"""Pallas kernels (L1) + pure-jnp oracles for the SFW-asyn compute hot path.

Exports:
  ms_grad   — fused matrix-sensing SUM-gradient + SUM-loss kernel
  pnn_grad  — fused PNN quadratic-forward + smooth-hinge gradient kernel
  mv / mtv  — tiled (transposed) matvec kernels for the power-iteration LMO
  ref       — pure-jnp oracles (tests only; never lowered to artifacts)
"""

from . import ref  # noqa: F401
from .matvec import mtv, mv  # noqa: F401
from .ms_grad import ms_grad, pick_tile  # noqa: F401
from .pnn_grad import pnn_grad  # noqa: F401
