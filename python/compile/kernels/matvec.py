"""L1 Pallas kernels: tiled matvec / transposed matvec for the LMO.

The nuclear-norm LMO is argmin_{||U||*<=theta} <G, U> = -theta * u1 v1^T
where (u1, v1) is the leading singular pair of the (minibatch) gradient G.
We compute it by alternating power iteration, whose inner ops are exactly
these two kernels:

    mv : u <- G  @ v   tiled over rows of G (each grid step holds a
                       (TILE_R, D2) block of G in VMEM and emits TILE_R
                       entries of u),
    mtv: v <- G^T @ u  tiled over *columns* of G (each grid step holds a
                       (D1, TILE_C) block and emits TILE_C entries of v) —
                       G is kept in its natural layout so the HBM->VMEM
                       schedule, not a transpose materialization, expresses
                       the access pattern.

On TPU these keep the gradient matrix resident across the iteration sweep;
interpret=True here (see ms_grad.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ms_grad import pick_tile


def _mv_kernel(g_ref, v_ref, o_ref):
    o_ref[...] = g_ref[...] @ v_ref[...]


def _mtv_kernel(g_ref, u_ref, o_ref):
    o_ref[...] = u_ref[...] @ g_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_r",))
def mv(g, v, *, tile_r: int | None = None):
    """u = G @ v with row-tiled G. g: (D1, D2), v: (D2,) -> (D1,)."""
    d1, d2 = g.shape
    tile = tile_r or pick_tile(d1, cap=256)
    assert d1 % tile == 0
    if tile == d1:
        return pl.pallas_call(
            _mv_kernel,
            out_shape=jax.ShapeDtypeStruct((d1,), jnp.float32),
            interpret=True,
        )(g, v)
    return pl.pallas_call(
        _mv_kernel,
        grid=(d1 // tile,),
        in_specs=[
            pl.BlockSpec((tile, d2), lambda i: (i, 0)),
            pl.BlockSpec((d2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d1,), jnp.float32),
        interpret=True,
    )(g, v)


@functools.partial(jax.jit, static_argnames=("tile_c",))
def mtv(g, u, *, tile_c: int | None = None):
    """v = G^T @ u with column-tiled G. g: (D1, D2), u: (D1,) -> (D2,)."""
    d1, d2 = g.shape
    tile = tile_c or pick_tile(d2, cap=256)
    assert d2 % tile == 0
    if tile == d2:
        return pl.pallas_call(
            _mtv_kernel,
            out_shape=jax.ShapeDtypeStruct((d2,), jnp.float32),
            interpret=True,
        )(g, u)
    return pl.pallas_call(
        _mtv_kernel,
        grid=(d2 // tile,),
        in_specs=[
            pl.BlockSpec((d1, tile), lambda i: (0, i)),
            pl.BlockSpec((d1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d2,), jnp.float32),
        interpret=True,
    )(g, u)
