"""L1 Pallas kernel: matrix-sensing minibatch gradient (+ loss), fused.

Computes, for a flattened sensing batch Af (m, K), responses y (m,) and a
flattened iterate xf (K,):

    r        = Af @ xf - y                      (residuals)
    grad_sum = 2 * Af^T r        (shape (K,))   — SUM over batch, not mean
    loss_sum = sum(r^2)

The batch dimension is tiled (BlockSpec over rows of Af): each grid step
loads a (TILE_M, K) block of Af into VMEM, forms its residual slice against
the resident xf, and accumulates the partial A^T r product into a VMEM
accumulator.  HBM traffic is therefore a single pass over Af per step — the
paper's workers did the same thing as a BLAS GEMV loop over MPI ranks; here
the whole contraction is one MXU-friendly kernel (see DESIGN.md
§Hardware-Adaptation for the VMEM/MXU sizing).

Pallas runs in interpret mode (CPU PJRT cannot execute Mosaic custom-calls);
the structure — not interpret-mode wallclock — is the optimization target.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ms_grad_kernel_single(af_ref, y_ref, xf_ref, grad_ref, loss_ref):
    """Gridless single-block variant: one VMEM-resident block, no grid
    machinery (the interpret-mode grid loop lowers to dynamic-slice chains
    that old CPU XLA cannot fuse; see model.py's CPU-interpret note)."""
    af = af_ref[...]
    r = af @ xf_ref[...] - y_ref[...]
    grad_ref[...] = 2.0 * (r @ af)
    loss_ref[...] = jnp.sum(r * r)


def _ms_grad_kernel(af_ref, y_ref, xf_ref, grad_ref, loss_ref):
    """One batch tile: accumulate 2*Af_tile^T r_tile and sum(r_tile^2)."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        grad_ref[...] = jnp.zeros_like(grad_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    af = af_ref[...]                      # (TILE_M, K) in VMEM
    r = af @ xf_ref[...] - y_ref[...]     # (TILE_M,)
    grad_ref[...] += 2.0 * (r @ af)       # partial Af^T r, stays in VMEM
    loss_ref[...] += jnp.sum(r * r)


def pick_tile(m: int, cap: int = 512) -> int:
    """Largest power-of-two tile <= cap that divides m (m is a power-of-two
    bucket in production; for odd test shapes fall back to m itself)."""
    t = cap
    while t > 1 and m % t != 0:
        t //= 2
    return max(t, 1)


@functools.partial(jax.jit, static_argnames=("tile_m",))
def ms_grad(af, y, xf, *, tile_m: int | None = None):
    """Fused matrix-sensing SUM-gradient + SUM-loss.

    Args:
      af: (m, K) float32 — flattened sensing matrices, K = D1*D2.
      y:  (m,)  float32 — responses.
      xf: (K,)  float32 — flattened iterate.
      tile_m: batch tile (rows of Af per grid step); default picked to
        divide m.
    Returns:
      (grad_sum (K,), loss_sum ()) — divide by the true m downstream.
    """
    m, k = af.shape
    tile = tile_m or pick_tile(m)
    assert m % tile == 0, f"batch {m} not divisible by tile {tile}"
    if tile == m:
        # single block: emit a gridless pallas_call (fast on CPU interpret)
        return pl.pallas_call(
            _ms_grad_kernel_single,
            out_shape=[
                jax.ShapeDtypeStruct((k,), jnp.float32),
                jax.ShapeDtypeStruct((), jnp.float32),
            ],
            interpret=True,
        )(af, y, xf)
    grid = (m // tile,)
    grad, loss = pl.pallas_call(
        _ms_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, k), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((), lambda i: ()),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        ],
        interpret=True,
    )(af, y, xf)
    return grad, loss
