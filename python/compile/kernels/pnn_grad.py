"""L1 Pallas kernel: polynomial-neural-network minibatch gradient (+ loss).

For a 2-layer PNN with quadratic activation and smooth hinge loss (paper
§5.1), a feature batch A (m, D), labels y (m,) in {-1, +1} and iterate
X (D, D):

    z_i      = a_i^T X a_i                      (quadratic forward)
    ty_i     = y_i * z_i
    loss_sum = sum_i s-hinge(ty_i)
    g_i      = s-hinge'(ty_i) * y_i
    grad_sum = A^T diag(g) A     (shape (D, D)) — SUM over batch, not mean

Fusion story (the reason this is a kernel and not three jnp calls): the
(TILE_M, D) intermediate A_tile @ X never leaves VMEM — forward scores,
hinge gradient weighting and the rank-TILE_M outer-product accumulation all
happen on the resident tile.  On real TPU hardware this is two MXU
contractions per tile with zero HBM round-trips for intermediates; the HBM
traffic is exactly one read of A per step plus the resident X.

Interpret mode only (see ms_grad.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ms_grad import pick_tile


def _pnn_grad_kernel_single(a_ref, y_ref, x_ref, grad_ref, loss_ref):
    """Gridless single-block variant (see ms_grad.py)."""
    a = a_ref[...]
    y = y_ref[...]
    ax = a @ x_ref[...]
    z = jnp.sum(ax * a, axis=1)
    ty = y * z
    loss = jnp.where(
        ty <= 0.0, 0.5 - ty, jnp.where(ty <= 1.0, 0.5 * (1.0 - ty) ** 2, 0.0)
    )
    loss = jnp.where(y == 0.0, 0.0, loss)
    dt = jnp.where(ty <= 0.0, -1.0, jnp.where(ty <= 1.0, -(1.0 - ty), 0.0))
    g = dt * y
    grad_ref[...] = a.T @ (g[:, None] * a)
    loss_ref[...] = jnp.sum(loss)


def _pnn_grad_kernel(a_ref, y_ref, x_ref, grad_ref, loss_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        grad_ref[...] = jnp.zeros_like(grad_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    a = a_ref[...]                         # (TILE_M, D)
    y = y_ref[...]                         # (TILE_M,)
    ax = a @ x_ref[...]                    # (TILE_M, D), stays in VMEM
    z = jnp.sum(ax * a, axis=1)            # quadratic forward scores
    ty = y * z
    # continuous smooth hinge (see kernels/ref.py for the typo note)
    loss = jnp.where(
        ty <= 0.0, 0.5 - ty, jnp.where(ty <= 1.0, 0.5 * (1.0 - ty) ** 2, 0.0)
    )
    # Padding rows carry y == 0 (real labels are ±1); they must contribute
    # exactly zero loss — s-hinge(0) = 0.5 would otherwise leak in.
    loss = jnp.where(y == 0.0, 0.0, loss)
    dt = jnp.where(ty <= 0.0, -1.0, jnp.where(ty <= 1.0, -(1.0 - ty), 0.0))
    g = dt * y                             # dl_i/dz_i
    grad_ref[...] += a.T @ (g[:, None] * a)
    loss_ref[...] += jnp.sum(loss)


@functools.partial(jax.jit, static_argnames=("tile_m",))
def pnn_grad(a, y, x, *, tile_m: int | None = None):
    """Fused PNN SUM-gradient + SUM-loss.

    Args:
      a: (m, D) float32 feature rows; y: (m,) float32 labels in {-1,+1}
        (0 rows with y=0 contribute exactly zero — used for bucket padding);
      x: (D, D) float32 iterate.
    Returns:
      (grad_sum (D, D), loss_sum ()).
    """
    m, d = a.shape
    tile = tile_m or pick_tile(m, cap=256)
    assert m % tile == 0, f"batch {m} not divisible by tile {tile}"
    if tile == m:
        return pl.pallas_call(
            _pnn_grad_kernel_single,
            out_shape=[
                jax.ShapeDtypeStruct((d, d), jnp.float32),
                jax.ShapeDtypeStruct((), jnp.float32),
            ],
            interpret=True,
        )(a, y, x)
    grid = (m // tile,)
    grad, loss = pl.pallas_call(
        _pnn_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((), lambda i: ()),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, d), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        ],
        interpret=True,
    )(a, y, x)
    return grad, loss
