"""Pure-jnp oracles for every Pallas kernel (the CORE correctness signal).

Each function here is the mathematically transparent reference; the Pallas
kernels in this package must match these to float32 tolerance under pytest
(+ hypothesis shape sweeps).  Nothing in this file is ever lowered to an
artifact — it exists only to test the kernels and the L2 model graphs.

Conventions (shared with the kernels and the Rust runtime):
  * gradients are returned as SUMS over the batch, not means — the caller
    divides by the true (un-padded) batch size, which makes zero-padding
    rows exact (a zero row contributes exactly zero to grad and loss),
  * matrix-sensing operates on flattened sensing matrices: Af[i] =
    vec(A_i) with K = D1*D2,
  * PNN uses the *continuous* smooth hinge: 0.5 - ty for ty <= 0,
    0.5*(1-ty)^2 for 0 <= ty <= 1, 0 otherwise.  (The paper prints
    (0.5*(1-ty))^2, which is discontinuous at ty = 0 and is evidently a
    typo for the standard smooth hinge; see DESIGN.md.)
"""

import jax.numpy as jnp


def ms_residual(af, y, xf):
    """Matrix-sensing residuals r_i = <A_i, X> - y_i on flattened inputs."""
    return af @ xf - y


def ms_grad_ref(af, y, xf):
    """SUM gradient + SUM loss of F(X) = (1/m) sum (<A_i,X> - y_i)^2.

    grad_sum = 2 * Af^T r  (flattened, shape (K,)); loss_sum = sum r^2.
    Caller divides both by the true batch size m.
    """
    r = ms_residual(af, y, xf)
    return 2.0 * (r @ af), jnp.sum(r * r)


def ms_loss_ref(af, y, xf):
    """SUM of squared residuals (caller divides by m)."""
    r = ms_residual(af, y, xf)
    return jnp.sum(r * r)


def smooth_hinge(ty):
    """Continuous smooth hinge loss as a function of the margin ty."""
    return jnp.where(
        ty <= 0.0,
        0.5 - ty,
        jnp.where(ty <= 1.0, 0.5 * (1.0 - ty) ** 2, 0.0),
    )


def smooth_hinge_dt(ty):
    """d smooth_hinge / d(ty): -1 for ty<=0, -(1-ty) on [0,1], 0 after."""
    return jnp.where(
        ty <= 0.0,
        -1.0,
        jnp.where(ty <= 1.0, -(1.0 - ty), 0.0),
    )


def pnn_forward(a, x):
    """Quadratic-activation PNN scores z_i = a_i^T X a_i."""
    return jnp.sum((a @ x) * a, axis=1)


def pnn_grad_ref(a, y, x):
    """SUM gradient + SUM loss of F(X) = (1/m) sum s-hinge(y_i, a_i^T X a_i).

    dl_i/dX = s-hinge'(ty_i) * y_i * a_i a_i^T  (chain rule through z_i),
    so grad_sum = A^T diag(g) A with g_i = s-hinge'(ty_i) * y_i.
    """
    z = pnn_forward(a, x)
    ty = y * z
    g = smooth_hinge_dt(ty) * y
    loss = jnp.where(y == 0.0, 0.0, smooth_hinge(ty))  # mask padding rows
    return a.T @ (g[:, None] * a), jnp.sum(loss)


def pnn_loss_ref(a, y, x):
    z = pnn_forward(a, x)
    return jnp.sum(jnp.where(y == 0.0, 0.0, smooth_hinge(y * z)))


def mv_ref(g, v):
    """Dense matvec G @ v."""
    return g @ v


def mtv_ref(g, u):
    """Dense transposed matvec G^T @ u."""
    return g.T @ u


def lmo_svd_ref(g):
    """Exact leading singular triple of G via full SVD (oracle for the
    power-iteration LMO).  Returns (u, v, sigma)."""
    uu, ss, vvt = jnp.linalg.svd(g, full_matrices=False)
    return uu[:, 0], vvt[0, :], ss[0]
