"""L2 model graphs: LMO power iteration vs exact SVD; fused step modules
vs the composition of their parts (what the Rust runtime assumes)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand_lowrankish(seed, d1, d2, gap=3.0):
    """Random matrix with a boosted top singular value so power iteration
    converges fast (gradient matrices in FW have this structure: the top
    direction dominates once X is far from optimal)."""
    r = np.random.default_rng(seed)
    g = r.standard_normal((d1, d2)).astype(np.float32)
    u = r.standard_normal(d1).astype(np.float32)
    v = r.standard_normal(d2).astype(np.float32)
    u /= np.linalg.norm(u)
    v /= np.linalg.norm(v)
    return jnp.asarray(g + gap * np.sqrt(d1 * d2) * np.outer(u, v))


@settings(max_examples=15, deadline=None)
@given(
    d1=st.sampled_from([4, 16, 30]),
    d2=st.sampled_from([4, 16, 30]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lmo_power_matches_svd(d1, d2, seed):
    g = rand_lowrankish(seed, d1, d2)
    v0 = jnp.ones(d2, jnp.float32)
    u, v, sigma = model.lmo_power(g, v0, 32)
    u_r, v_r, s_r = ref.lmo_svd_ref(g)
    # singular vectors are sign-ambiguous; compare |<u, u_ref>| and sigma
    assert abs(float(jnp.dot(u, u_r))) > 0.999
    assert abs(float(jnp.dot(v, v_r))) > 0.999
    np.testing.assert_allclose(float(sigma), float(s_r), rtol=1e-3)


def test_lmo_power_unit_norm_outputs():
    g = rand_lowrankish(3, 30, 30)
    u, v, sigma = model.lmo_power(g, jnp.ones(30, jnp.float32), 16)
    assert abs(float(jnp.linalg.norm(u)) - 1.0) < 1e-4
    assert abs(float(jnp.linalg.norm(v)) - 1.0) < 1e-4
    assert float(sigma) >= 0.0


def test_lmo_descent_direction():
    """-u v^T must be the best rank-one direction: <G, -uv^T> <= <G, -ab^T>
    for random unit pairs (a, b)."""
    g = rand_lowrankish(17, 20, 12)
    u, v, _ = model.lmo_power(g, jnp.ones(12, jnp.float32), 32)
    best = float(jnp.einsum("ij,i,j->", g, u, v))
    r = np.random.default_rng(0)
    for _ in range(50):
        a = r.standard_normal(20).astype(np.float32)
        b = r.standard_normal(12).astype(np.float32)
        a /= np.linalg.norm(a)
        b /= np.linalg.norm(b)
        cand = float(jnp.einsum("ij,i,j->", g, jnp.asarray(a), jnp.asarray(b)))
        assert cand <= best + 1e-3


def test_ms_step_equals_composition():
    r = np.random.default_rng(5)
    m, d1, d2 = 64, 6, 5
    af = jnp.asarray(r.standard_normal((m, d1 * d2)).astype(np.float32))
    y = jnp.asarray(r.standard_normal(m).astype(np.float32))
    xf = jnp.asarray(r.standard_normal(d1 * d2).astype(np.float32) * 0.1)
    v0 = jnp.ones(d2, jnp.float32)
    u, v, sigma, loss = model.ms_step(af, y, xf, v0, d1=d1, d2=d2, power_iters=32)
    g_r, l_r = ref.ms_grad_ref(af, y, xf)
    u_r, v_r, s_r = ref.lmo_svd_ref(g_r.reshape(d1, d2))
    np.testing.assert_allclose(float(loss), float(l_r), rtol=1e-4)
    np.testing.assert_allclose(float(sigma), float(s_r), rtol=1e-2)
    assert abs(float(jnp.dot(u, u_r))) > 0.99


def test_pnn_step_equals_composition():
    r = np.random.default_rng(6)
    m, d = 64, 8
    a = jnp.asarray(r.random((m, d)).astype(np.float32))
    y = jnp.asarray(np.where(r.random(m) < 0.5, -1.0, 1.0).astype(np.float32))
    x = jnp.asarray(r.standard_normal((d, d)).astype(np.float32) * 0.05)
    v0 = jnp.ones(d, jnp.float32)
    u, v, sigma, loss = model.pnn_step(a, y, x, v0, power_iters=32)
    g_r, l_r = ref.pnn_grad_ref(a, y, x)
    u_r, v_r, s_r = ref.lmo_svd_ref(g_r)
    np.testing.assert_allclose(float(loss), float(l_r), rtol=1e-4)
    np.testing.assert_allclose(float(sigma), float(s_r), rtol=1e-2)


def test_loss_modules_match_ref():
    r = np.random.default_rng(8)
    af = jnp.asarray(r.standard_normal((32, 16)).astype(np.float32))
    y = jnp.asarray(r.standard_normal(32).astype(np.float32))
    xf = jnp.asarray(r.standard_normal(16).astype(np.float32))
    (l,) = model.ms_loss_module(af, y, xf)
    np.testing.assert_allclose(float(l), float(ref.ms_loss_ref(af, y, xf)), rtol=1e-4)

    a = jnp.asarray(r.random((32, 8)).astype(np.float32))
    yl = jnp.asarray(np.where(r.random(32) < 0.5, -1.0, 1.0).astype(np.float32))
    x = jnp.asarray(r.standard_normal((8, 8)).astype(np.float32) * 0.1)
    (l2,) = model.pnn_loss_module(a, yl, x)
    np.testing.assert_allclose(float(l2), float(ref.pnn_loss_ref(a, yl, x)), rtol=1e-4)
