"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

hypothesis sweeps batch sizes / dims / tiles; every kernel must match
kernels/ref.py to float32 tolerance, including the padding conventions
(zero rows contribute exactly zero grad AND zero loss).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ms_grad, mtv, mv, pick_tile, pnn_grad, ref

jax.config.update("jax_platform_name", "cpu")

RTOL, ATOL = 2e-4, 2e-4


def rng(seed):
    return np.random.default_rng(seed)


def ms_batch(seed, m, d1, d2):
    r = rng(seed)
    af = r.standard_normal((m, d1 * d2), dtype=np.float32)
    xf = r.standard_normal(d1 * d2, dtype=np.float32) * 0.1
    y = r.standard_normal(m, dtype=np.float32)
    return jnp.asarray(af), jnp.asarray(y), jnp.asarray(xf)


def pnn_batch(seed, m, d):
    r = rng(seed)
    a = r.random((m, d), dtype=np.float32)
    y = np.where(r.random(m) < 0.5, -1.0, 1.0).astype(np.float32)
    x = (r.standard_normal((d, d), dtype=np.float32) * 0.05).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(y), jnp.asarray(x)


# ------------------------------------------------------------------ ms_grad


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([4, 16, 64, 128, 256]),
    d1=st.sampled_from([2, 5, 8, 30]),
    d2=st.sampled_from([2, 7, 30]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ms_grad_matches_ref(m, d1, d2, seed):
    af, y, xf = ms_batch(seed, m, d1, d2)
    g_k, l_k = ms_grad(af, y, xf)
    g_r, l_r = ref.ms_grad_ref(af, y, xf)
    np.testing.assert_allclose(g_k, g_r, rtol=RTOL, atol=ATOL * m)
    np.testing.assert_allclose(l_k, l_r, rtol=RTOL, atol=ATOL * m)


@pytest.mark.parametrize("tile", [1, 2, 4, 8, 16, 32, 64])
def test_ms_grad_tile_invariance(tile):
    af, y, xf = ms_batch(7, 64, 6, 5)
    g0, l0 = ref.ms_grad_ref(af, y, xf)
    g, l = ms_grad(af, y, xf, tile_m=tile)
    np.testing.assert_allclose(g, g0, rtol=RTOL, atol=ATOL * 64)
    np.testing.assert_allclose(l, l0, rtol=RTOL, atol=ATOL * 64)


def test_ms_grad_zero_padding_exact():
    af, y, xf = ms_batch(3, 32, 4, 4)
    afp = jnp.concatenate([af, jnp.zeros((32, 16), jnp.float32)])
    yp = jnp.concatenate([y, jnp.zeros(32, jnp.float32)])
    g0, l0 = ms_grad(af, y, xf)
    g1, l1 = ms_grad(afp, yp, xf)
    np.testing.assert_allclose(g1, g0, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(l1, l0, rtol=RTOL, atol=ATOL)


def test_ms_grad_is_true_gradient():
    """Finite-difference check: kernel sum-grad/m == dF/dx elementwise."""
    af, y, xf = ms_batch(11, 32, 3, 3)
    m = 32
    g, _ = ms_grad(af, y, xf)
    g = np.asarray(g) / m
    eps = 1e-3
    for idx in [0, 4, 8]:
        e = np.zeros(9, np.float32)
        e[idx] = eps
        fp = float(ref.ms_loss_ref(af, y, xf + e)) / m
        fm = float(ref.ms_loss_ref(af, y, xf - e)) / m
        fd = (fp - fm) / (2 * eps)
        assert abs(fd - g[idx]) < 5e-2, (idx, fd, g[idx])


# ----------------------------------------------------------------- pnn_grad


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([4, 16, 64, 128]),
    d=st.sampled_from([3, 8, 14, 28]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pnn_grad_matches_ref(m, d, seed):
    a, y, x = pnn_batch(seed, m, d)
    g_k, l_k = pnn_grad(a, y, x)
    g_r, l_r = ref.pnn_grad_ref(a, y, x)
    np.testing.assert_allclose(g_k, g_r, rtol=RTOL, atol=ATOL * m)
    np.testing.assert_allclose(l_k, l_r, rtol=RTOL, atol=ATOL * m)


@pytest.mark.parametrize("tile", [1, 4, 16, 64])
def test_pnn_grad_tile_invariance(tile):
    a, y, x = pnn_batch(5, 64, 9)
    g0, l0 = ref.pnn_grad_ref(a, y, x)
    g, l = pnn_grad(a, y, x, tile_m=tile)
    np.testing.assert_allclose(g, g0, rtol=RTOL, atol=ATOL * 64)
    np.testing.assert_allclose(l, l0, rtol=RTOL, atol=ATOL * 64)


def test_pnn_zero_padding_exact():
    """Padding rows (a=0, y=0) contribute zero grad AND zero loss — the
    s-hinge(0)=0.5 leak is masked (kernels/pnn_grad.py)."""
    a, y, x = pnn_batch(9, 16, 6)
    ap = jnp.concatenate([a, jnp.zeros((48, 6), jnp.float32)])
    yp = jnp.concatenate([y, jnp.zeros(48, jnp.float32)])
    g0, l0 = pnn_grad(a, y, x)
    g1, l1 = pnn_grad(ap, yp, x)
    np.testing.assert_allclose(g1, g0, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(l1, l0, rtol=RTOL, atol=ATOL)


def test_pnn_grad_is_true_gradient():
    a, y, x = pnn_batch(21, 32, 4)
    m = 32
    g, _ = pnn_grad(a, y, x)
    g = np.asarray(g) / m
    eps = 1e-3
    for i, j in [(0, 0), (1, 2), (3, 3)]:
        e = np.zeros((4, 4), np.float32)
        e[i, j] = eps
        fp = float(ref.pnn_loss_ref(a, y, x + e)) / m
        fm = float(ref.pnn_loss_ref(a, y, x - e)) / m
        fd = (fp - fm) / (2 * eps)
        assert abs(fd - g[i, j]) < 5e-2, ((i, j), fd, g[i, j])


def test_smooth_hinge_continuity():
    """Regression for the paper's (0.5*(1-ty))^2 typo: our hinge is
    continuous at ty=0 and ty=1 and matches the linear branch for ty<0."""
    ty = jnp.asarray([-1e-4, 0.0, 1e-4, 1.0 - 1e-4, 1.0, 1.0 + 1e-4])
    v = np.asarray(ref.smooth_hinge(ty))
    assert abs(v[0] - v[1]) < 1e-3 and abs(v[1] - v[2]) < 1e-3
    assert abs(v[1] - 0.5) < 1e-6
    assert v[4] == 0.0 and v[5] == 0.0 and abs(v[3]) < 1e-6


# ------------------------------------------------------------------- matvec


@settings(max_examples=20, deadline=None)
@given(
    d1=st.sampled_from([2, 8, 30, 64]),
    d2=st.sampled_from([3, 16, 30]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_matches_ref(d1, d2, seed):
    r = rng(seed)
    g = jnp.asarray(r.standard_normal((d1, d2), dtype=np.float32))
    v = jnp.asarray(r.standard_normal(d2, dtype=np.float32))
    u = jnp.asarray(r.standard_normal(d1, dtype=np.float32))
    np.testing.assert_allclose(mv(g, v), ref.mv_ref(g, v), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(mtv(g, u), ref.mtv_ref(g, u), rtol=RTOL, atol=ATOL)


def test_pick_tile():
    assert pick_tile(1024) == 512
    assert pick_tile(64) == 64
    assert pick_tile(96) == 32
    assert pick_tile(7) == 1  # odd shapes fall back to untiled
