"""AOT smoke: --quick build produces parseable HLO text + coherent manifest."""

import os

from compile import aot


def test_quick_build(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.main([
        "--out-dir", out, "--quick",
        "--ms-d1", "6", "--ms-d2", "5", "--pnn-d", "8", "--power-iters", "4",
    ])
    names = sorted(os.listdir(out))
    assert "manifest.txt" in names
    expected = [
        "lmo_ms", "lmo_pnn",
        "ms_grad_m64", "ms_loss_m64", "ms_step_m64", "ms_stepi_m64",
        "pnn_grad_m64", "pnn_loss_m64", "pnn_step_m64", "pnn_stepi_m64",
    ]
    for n in expected:
        path = os.path.join(out, f"{n}.hlo.txt")
        assert os.path.exists(path), n
        text = open(path).read()
        assert text.startswith("HloModule"), f"{n} is not HLO text"
        assert "ROOT" in text

    manifest = open(os.path.join(out, "manifest.txt")).read().splitlines()
    params = {l.split()[1]: l.split()[2] for l in manifest if l.startswith("param ")}
    assert params["ms_d1"] == "6" and params["ms_d2"] == "5"
    assert params["pnn_d"] == "8"
    assert params["ms_buckets"] == "64" and params["pnn_buckets"] == "64"
    modules = [l.split()[1] for l in manifest if l.startswith("module ")]
    assert sorted(modules) == expected


def test_manifest_input_shapes(tmp_path):
    out = str(tmp_path / "a2")
    aot.main(["--out-dir", out, "--quick", "--ms-d1", "4", "--ms-d2", "4",
              "--pnn-d", "4", "--power-iters", "2"])
    lines = open(os.path.join(out, "manifest.txt")).read().splitlines()
    step = next(l for l in lines if l.startswith("module ms_step_m64"))
    assert "inputs=64x16,64,16,4" in step
    lmo = next(l for l in lines if l.startswith("module lmo_pnn"))
    assert "inputs=4x4,4" in lmo
    stepi = next(l for l in lines if l.startswith("module ms_stepi_m64"))
    # N_max+1 rows (zero pad row), i32 index vector
    assert "inputs=513x16,513,64,16,4" in stepi
