//! Appendix-D queuing-model demo: how much does asynchrony buy as worker
//! heterogeneity (the geometric staleness parameter p) varies?
//!
//!     cargo run --release --example queuing_sim -- [--workers 15]
//!         [--iterations 300] [--batch 128]
//!
//! Reproduces the *shape* of Fig 6/7: near-linear speedup for SFW-asyn
//! under heavy-tailed workers (p = 0.1), shrinking gap as p -> 1.

use std::sync::Arc;

use sfw::algo::engine::NativeEngine;
use sfw::algo::schedule::BatchSchedule;
use sfw::benchkit::Table;
use sfw::experiments::build_ms;
use sfw::objective::Objective;
use sfw::sim::{simulate_asyn, simulate_dist, QueuingParams};
use sfw::util::cli::Args;

fn main() {
    let args = Args::parse_env(1);
    let workers = args.get_usize("workers", 15);
    let iterations = args.get_u64("iterations", 300);
    let batch = args.get_usize("batch", 128);
    let seed = args.get_u64("seed", 42);

    let obj = build_ms(seed, 20_000);
    let o: Arc<dyn Objective> = obj.clone();
    println!(
        "queuing model: matrix sensing, W={workers}, T={iterations}, m={batch}\n\
         (1 unit = one D1*D2 op; grad eval = 1 unit/sample, 1-SVD = 10 units;\n\
         communication free — the model favors SFW-dist, Appendix D)"
    );

    let mut table = Table::new(
        "virtual time to finish T iterations",
        &["p", "SFW-dist", "SFW-asyn", "speedup"],
    );
    for p in [0.1, 0.3, 0.5, 0.8, 1.0] {
        let prm = QueuingParams {
            workers,
            p,
            iterations,
            tau: 2 * workers as u64,
            batch: BatchSchedule::Constant(batch),
            eval_every: iterations,
            seed,
            ..Default::default()
        };
        let mut engines: Vec<NativeEngine> = (0..workers)
            .map(|w| NativeEngine::new(o.clone(), 30, seed ^ w as u64))
            .collect();
        let ra = simulate_asyn(o.clone(), &mut engines, &prm);
        let mut e1 = vec![NativeEngine::new(o.clone(), 30, seed ^ 0xFF)];
        let rd = simulate_dist(o.clone(), &mut e1, &prm);
        table.row(&[
            format!("{p:.1}"),
            format!("{:.0}", rd.virtual_time),
            format!("{:.0}", ra.virtual_time),
            format!("{:.2}x", rd.virtual_time / ra.virtual_time),
        ]);
    }
    table.print();
    println!(
        "\nExpected shape (paper Fig 6/7): the speedup column shrinks toward\n\
         1x as p -> 1 (uniform workers) and is largest for p = 0.1."
    );
}
