//! The paper's first evaluation workload (§5.1): matrix sensing on
//! synthetic data — X* rank-3, 30x30, N sensing matrices, noisy responses.
//! Compares serial SFW, synchronous SFW-dist, and SFW-asyn head to head
//! with identical budgets and reports time-to-target + communication.
//!
//!     cargo run --release --example matrix_sensing -- [--n 90000]
//!         [--workers 8] [--iterations 400] [--target 0.01]

use std::sync::Arc;

use sfw::algo::engine::NativeEngine;
use sfw::algo::schedule::BatchSchedule;
use sfw::algo::sfw::{run_sfw, SfwOptions};
use sfw::benchkit::Table;
use sfw::coordinator::{run_asyn_local, run_dist, AsynOptions, DistOptions};
use sfw::experiments::{build_ms, time_to_relative};
use sfw::metrics::{Counters, LossTrace};
use sfw::objective::Objective;
use sfw::util::cli::Args;

fn main() {
    let args = Args::parse_env(1);
    let n = args.get_usize("n", 30_000);
    let workers = args.get_usize("workers", 8);
    let iterations = args.get_u64("iterations", 400);
    let tau = args.get_u64("tau", 8);
    let target = args.get_f64("target", 0.01);
    let seed = args.get_u64("seed", 42);

    println!("matrix sensing: N={n}, D=30x30, W={workers}, T={iterations}, tau={tau}");
    let obj = build_ms(seed, n);
    let o: Arc<dyn Objective> = obj.clone();
    let f_star = o.f_star_hint();
    let cap = 10_000; // paper's MS batch cap

    let mut table = Table::new(
        "matrix sensing: time to relative loss",
        &["algorithm", "workers", "t_target(s)", "final rel", "grad evals", "up bytes"],
    );

    // serial SFW
    {
        let counters = Counters::new();
        let trace = LossTrace::new();
        let mut engine = NativeEngine::new(o.clone(), 40, seed ^ 1);
        let opts = SfwOptions {
            iterations,
            batch: BatchSchedule::sfw(2.0, cap),
            eval_every: 10,
            seed,
        };
        run_sfw(&mut engine, &opts, &counters, &trace);
        report(&mut table, "SFW (serial)", 1, &trace.points(), f_star, target, &counters.snapshot());
    }
    // SFW-dist
    {
        let o2 = obj.clone();
        let r = run_dist(
            o.clone(),
            &DistOptions {
                iterations,
                workers,
                batch: BatchSchedule::sfw(2.0, cap),
                eval_every: 10,
                seed,
                straggler: None,
            },
            move |w| Box::new(NativeEngine::new(o2.clone(), 40, seed ^ 0x20u64.wrapping_add(w as u64))),
        );
        report(&mut table, "SFW-dist", workers, &r.trace.points(), f_star, target, &r.counters.snapshot());
    }
    // SFW-asyn
    {
        let o2 = obj.clone();
        let r = run_asyn_local(
            o.clone(),
            &AsynOptions {
                iterations,
                tau,
                workers,
                batch: BatchSchedule::sfw(2.0, cap), // same schedule as dist: wall-clock comparison
                eval_every: 10,
                seed,
                straggler: None,
                link_latency: None,
            },
            move |w| Box::new(NativeEngine::new(o2.clone(), 40, seed ^ 0x30 ^ w as u64)),
        );
        report(&mut table, "SFW-asyn", workers, &r.trace.points(), f_star, target, &r.counters.snapshot());
    }
    table.print();
    println!("\n(relative loss = (F - F*) / (F_0 - F*); F* = noise floor)");
}

fn report(
    table: &mut Table,
    name: &str,
    workers: usize,
    pts: &[sfw::metrics::TracePoint],
    f_star: f64,
    target: f64,
    s: &sfw::metrics::CounterSnapshot,
) {
    let t = time_to_relative(pts, f_star, target)
        .map(|t| format!("{t:.3}"))
        .unwrap_or_else(|| "—".into());
    let final_rel = sfw::experiments::relative(pts, f_star)
        .last()
        .map(|(_, _, r)| format!("{r:.3e}"))
        .unwrap_or_default();
    table.row(&[
        name.into(),
        workers.to_string(),
        t,
        final_rel,
        s.grad_evals.to_string(),
        s.bytes_up.to_string(),
    ]);
}
