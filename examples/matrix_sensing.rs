//! The paper's first evaluation workload (§5.1): matrix sensing on
//! synthetic data — X* rank-3, 30x30, N sensing matrices, noisy responses.
//! Compares serial SFW, synchronous SFW-dist, and SFW-asyn head to head
//! with identical budgets and reports time-to-target + communication.
//!
//!     cargo run --release --example matrix_sensing -- [--n 90000]
//!         [--workers 8] [--iterations 400] [--target 0.01]

use sfw::benchkit::Table;
use sfw::experiments::build_ms;
use sfw::runtime::Workload;
use sfw::session::{BatchSchedule, Report, TaskSpec, TrainSpec};
use sfw::util::cli::Args;

fn main() {
    let args = Args::parse_env(1);
    let n = args.get_usize("n", 30_000);
    let workers = args.get_usize("workers", 8);
    let iterations = args.get_u64("iterations", 400);
    let tau = args.get_u64("tau", 8);
    let target = args.get_f64("target", 0.01);
    let seed = args.get_u64("seed", 42);

    println!("matrix sensing: N={n}, D=30x30, W={workers}, T={iterations}, tau={tau}");
    let cap = 10_000; // paper's MS batch cap
    let base = TrainSpec::new(TaskSpec::Prebuilt(Workload::Ms(build_ms(seed, n))))
        .iterations(iterations)
        .tau(tau)
        .workers(workers)
        .batch(BatchSchedule::sfw(2.0, cap)) // same schedule everywhere: wall-clock comparison
        .eval_every(10)
        .seed(seed)
        .power_iters(40);

    let mut table = Table::new(
        "matrix sensing: time to relative loss",
        &["algorithm", "workers", "t_target(s)", "final rel", "grad evals", "up bytes"],
    );

    let sfw = base.clone().algo("sfw").run().expect("sfw");
    report(&mut table, "SFW (serial)", 1, &sfw, target);
    let dist = base.clone().algo("sfw-dist").run().expect("sfw-dist");
    report(&mut table, "SFW-dist", workers, &dist, target);
    let asyn = base.clone().algo("sfw-asyn").run().expect("sfw-asyn");
    report(&mut table, "SFW-asyn", workers, &asyn, target);

    table.print();
    println!("\n(relative loss = (F - F*) / (F_0 - F*); F* = noise floor)");
}

fn report(table: &mut Table, name: &str, workers: usize, r: &Report, target: f64) {
    let t = r
        .time_to_relative(target)
        .map(|t| format!("{t:.3}"))
        .unwrap_or_else(|| "—".into());
    let s = r.snapshot();
    table.row(&[
        name.into(),
        workers.to_string(),
        t,
        format!("{:.3e}", r.final_relative()),
        s.grad_evals.to_string(),
        s.bytes_up.to_string(),
    ]);
}
