//! The paper's second evaluation workload (§5.1): a two-layer polynomial
//! neural network (quadratic activation, smooth hinge loss) trained under
//! a nuclear-norm constraint.  MNIST is replaced by the planted low-rank
//! quadratic teacher described in DESIGN.md §6 (no network access in this
//! environment); the experiment's subject — loss-vs-time when D1*D2 is
//! large enough that communication dominates — is preserved.
//!
//!     cargo run --release --example pnn_mnist -- [--d 196] [--n 20000]
//!         [--workers 8] [--iterations 150]

use sfw::experiments::build_pnn;
use sfw::runtime::Workload;
use sfw::session::{BatchSchedule, TaskSpec, TrainSpec};
use sfw::util::cli::Args;

fn main() {
    let args = Args::parse_env(1);
    let d = args.get_usize("d", 196); // 784 = full paper scale (28x28)
    let n = args.get_usize("n", 20_000);
    let workers = args.get_usize("workers", 8);
    let iterations = args.get_u64("iterations", 150);
    let tau = args.get_u64("tau", 8);
    let seed = args.get_u64("seed", 42);
    let cap = 3_000; // paper's PNN batch cap

    println!(
        "PNN: D={d}x{d} ({} params), N={n}, W={workers}, T={iterations}",
        d * d
    );
    let obj = build_pnn(seed, d, n);

    // dense-matrix traffic per SFW-dist round vs rank-one per asyn update:
    let dense = 4 * d * d;
    let rank1 = 4 * (d + d);
    println!(
        "wire sizes: dense gradient {dense} B vs rank-one update {rank1} B ({}x smaller)\n",
        dense / rank1
    );

    let base = TrainSpec::new(TaskSpec::Prebuilt(Workload::Pnn(obj.clone())))
        .iterations(iterations)
        .tau(tau)
        .workers(workers)
        .batch(BatchSchedule::sfw(2.0, cap)) // same schedule both algos: wall-clock comparison
        .eval_every(10)
        .seed(seed)
        .power_iters(30);
    let dist = base.clone().algo("sfw-dist").run().expect("sfw-dist");
    let asyn = base.clone().algo("sfw-asyn").run().expect("sfw-asyn");

    println!("   t(s)      SFW-dist rel      |    t(s)      SFW-asyn rel");
    let rd = dist.relative();
    let ra = asyn.relative();
    for i in 0..rd.len().max(ra.len()) {
        let left = rd
            .get(i)
            .map(|(t, _, r)| format!("{t:<9.3} {r:<17.4e}"))
            .unwrap_or_else(|| " ".repeat(27));
        let right = ra
            .get(i)
            .map(|(t, _, r)| format!("{t:<9.3} {r:.4e}"))
            .unwrap_or_default();
        println!("   {left} |    {right}");
    }

    let (sd, sa) = (dist.snapshot(), asyn.snapshot());
    println!("\ncomm totals (up): SFW-dist {} B, SFW-asyn {} B", sd.bytes_up, sa.bytes_up);
    println!(
        "train accuracy: SFW-dist {:.1}%, SFW-asyn {:.1}%",
        100.0 * obj.data.accuracy(&dist.x),
        100.0 * obj.data.accuracy(&asyn.x)
    );
}
