//! END-TO-END driver: the full three-layer stack on a real small workload.
//!
//!   L1/L2  Pallas kernels + JAX graphs, AOT-compiled once to HLO text
//!          (`make artifacts`) — Python is NOT running now.
//!   rt     Rust PJRT CPU client loads + executes the artifacts.
//!   L3     SFW-asyn master/workers exchanging rank-one updates over real
//!          localhost TCP sockets with the tau-staleness gate.
//!
//! Trains the PNN workload (D x D nuclear-constrained quadratic network,
//! the paper's large-model task) for a few hundred master iterations and
//! logs the loss curve; results are recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example e2e_full_system -- \
//!         [--iterations 300] [--workers 4] [--tau 8] [--n 20000] [--tcp]
//!
//! Requires `make artifacts`.  The PNN feature dim is read from the
//! artifact manifest (default 196; rebuild artifacts with --pnn-d 784 for
//! full paper scale).

use std::sync::Arc;

use sfw::experiments::build_pnn;
use sfw::objective::Objective;
use sfw::runtime::{loss_full_pjrt, PjrtRuntime, Workload};
use sfw::session::{BatchSchedule, TaskSpec, TrainSpec, Transport};
use sfw::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env(1);
    let iterations = args.get_u64("iterations", 300);
    let workers = args.get_usize("workers", 4);
    let tau = args.get_u64("tau", 8);
    let n = args.get_usize("n", 20_000);
    let seed = args.get_u64("seed", 42);
    let use_tcp = args.get_bool("tcp");
    let artifacts = args.get_str("artifacts-dir", "artifacts");

    // --- runtime + workload --------------------------------------------
    let rt = Arc::new(PjrtRuntime::new(&artifacts)?);
    let d = rt.manifest().param_usize("pnn_d")?;
    println!(
        "e2e: PJRT platform={}, artifacts={artifacts}, PNN D={d}x{d} ({} params)",
        rt.platform(),
        d * d
    );
    let obj = build_pnn(seed, d, n);
    let o: Arc<dyn Objective> = obj.clone();
    println!(
        "dataset: N={n} planted-teacher samples; transport={}; W={workers}, tau={tau}, T={iterations}",
        if use_tcp { "TCP (localhost)" } else { "in-process channels" }
    );

    // --- train: SFW-asyn entirely through the AOT artifacts -------------
    let t0 = std::time::Instant::now();
    let r = TrainSpec::new(TaskSpec::Prebuilt(Workload::Pnn(obj.clone())))
        .algo("sfw-asyn")
        .iterations(iterations)
        .tau(tau)
        .workers(workers)
        .batch(BatchSchedule::sfw(2.0, 2_048))
        .eval_every(20)
        .seed(seed)
        .pjrt_runtime(rt.clone()) // share the loaded artifacts with eval below
        .transport(if use_tcp { Transport::Tcp } else { Transport::Local })
        .run()?;
    let wall = t0.elapsed().as_secs_f64();

    // --- report ----------------------------------------------------------
    println!("\n   t(s)      iter   loss");
    for p in r.points() {
        println!("   {:<9.3} {:<6} {:.6e}", p.t, p.iteration, p.loss);
    }
    let s = r.snapshot();
    println!(
        "\n{} master iterations in {:.1}s ({:.1} iter/s), {} dropped by tau-gate",
        s.iterations,
        wall,
        s.iterations as f64 / wall,
        s.dropped_updates
    );
    println!(
        "comm: {} B up ({} msgs), {} B down ({} msgs) — rank-one protocol",
        s.bytes_up, s.msgs_up, s.bytes_down, s.msgs_down
    );
    println!(
        "gradient evaluations: {} (all through Pallas/XLA artifacts via PJRT)",
        s.grad_evals
    );

    // Final loss evaluated THROUGH the artifacts too (Python-free e2e).
    let loss_pjrt = loss_full_pjrt(&rt, &Workload::Pnn(obj.clone()), &r.x)?;
    let loss_native = o.loss_full(&r.x);
    println!(
        "\nfinal loss: {loss_pjrt:.6e} (PJRT eval) vs {loss_native:.6e} (native eval) — diff {:.2e}",
        (loss_pjrt - loss_native).abs()
    );
    println!("train accuracy: {:.1}%", 100.0 * obj.data.accuracy(&r.x));
    let pts = r.points();
    let (f0, f1) = (pts.first().unwrap().loss, pts.last().unwrap().loss);
    anyhow::ensure!(f1 < 0.9 * f0, "loss did not decrease: {f0} -> {f1}");
    println!("\ne2e OK: all three layers composed (Pallas -> XLA -> PJRT -> async coordinator).");
    Ok(())
}
