//! Quickstart: train SFW-asyn on a small matrix-sensing problem with 4
//! asynchronous workers and watch the relative loss fall.
//!
//!     cargo run --release --example quickstart
//!
//! Takes a few seconds.  For the full paper workloads see
//! `examples/matrix_sensing.rs`, `examples/pnn_mnist.rs`; for the
//! Python-free AOT/PJRT stack end to end see `examples/e2e_full_system.rs`.

use sfw::experiments::build_ms;
use sfw::runtime::Workload;
use sfw::session::{TaskSpec, TrainSpec};

fn main() {
    // 1. A nuclear-norm-constrained problem: recover a rank-3 30x30 matrix
    //    from 10 000 random linear measurements (paper §5.1, scaled down).
    let obj = build_ms(/*seed=*/ 7, /*n=*/ 10_000);
    println!("matrix sensing: N={} examples, D=30x30, theta=1", obj.data.n);

    // 2. SFW-asyn: 4 workers, staleness tolerance tau=8, the Theorem-1
    //    increasing batch schedule (tau^2 smaller than plain SFW's) —
    //    derived by the spec from batch_scale/tau/batch_cap.
    let report = TrainSpec::new(TaskSpec::Prebuilt(Workload::Ms(obj)))
        .algo("sfw-asyn")
        .iterations(300)
        .tau(8)
        .workers(4)
        .batch_scale(8.0)
        .batch_cap(4_096)
        .eval_every(20)
        .seed(42)
        .power_iters(40)
        .run()
        .expect("train");

    // 3. Report: relative loss curve + protocol counters.
    println!("\n   time(s)   iter   relative-loss");
    for (t, k, rel) in report.relative() {
        println!("   {t:<9.3} {k:<6} {rel:.4e}");
    }
    let s = report.snapshot();
    println!(
        "\nprotocol: {} accepted updates, {} dropped by the tau-gate,\n\
         {} B up / {} B down — every message O(D1+D2), never a dense matrix",
        s.iterations, s.dropped_updates, s.bytes_up, s.bytes_down
    );
}
