//! Quickstart: train SFW-asyn on a small matrix-sensing problem with 4
//! asynchronous workers and watch the relative loss fall.
//!
//!     cargo run --release --example quickstart
//!
//! Takes a few seconds.  For the full paper workloads see
//! `examples/matrix_sensing.rs`, `examples/pnn_mnist.rs`; for the
//! Python-free AOT/PJRT stack end to end see `examples/e2e_full_system.rs`.

use std::sync::Arc;

use sfw::algo::engine::NativeEngine;
use sfw::algo::schedule::BatchSchedule;
use sfw::coordinator::{run_asyn_local, AsynOptions};
use sfw::experiments::{build_ms, relative};
use sfw::objective::Objective;

fn main() {
    // 1. A nuclear-norm-constrained problem: recover a rank-3 30x30 matrix
    //    from 10 000 random linear measurements (paper §5.1, scaled down).
    let obj = build_ms(/*seed=*/ 7, /*n=*/ 10_000);
    let o: Arc<dyn Objective> = obj.clone();
    println!("matrix sensing: N={} examples, D=30x30, theta=1", o.n());

    // 2. SFW-asyn: 4 workers, staleness tolerance tau=8, the Theorem-1
    //    increasing batch schedule (tau^2 smaller than plain SFW's).
    let opts = AsynOptions {
        iterations: 300,
        tau: 8,
        workers: 4,
        batch: BatchSchedule::sfw_asyn(/*scale=*/ 8.0, /*tau=*/ 8, /*cap=*/ 4_096),
        eval_every: 20,
        seed: 42,
        straggler: None,
        link_latency: None,
    };
    let o2 = obj.clone();
    let result = run_asyn_local(o.clone(), &opts, move |w| {
        Box::new(NativeEngine::new(o2.clone(), 40, 100 + w as u64))
    });

    // 3. Report: relative loss curve + protocol counters.
    println!("\n   time(s)   iter   relative-loss");
    for (t, k, rel) in relative(&result.trace.points(), o.f_star_hint()) {
        println!("   {t:<9.3} {k:<6} {rel:.4e}");
    }
    let s = result.counters.snapshot();
    println!(
        "\nprotocol: {} accepted updates, {} dropped by the tau-gate,\n\
         {} B up / {} B down — every message O(D1+D2), never a dense matrix",
        s.iterations, s.dropped_updates, s.bytes_up, s.bytes_down
    );
}
